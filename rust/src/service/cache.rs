//! [`PlanCache`]: service-owned structural interning of [`MatExpr`]
//! subtrees, so concurrent jobs over the same data share plan **nodes** —
//! and therefore, through the executor's per-node memoization and the
//! exactly-once slot locking, share materialized **results**.
//!
//! ## The cross-job cache key
//!
//! Interning is keyed structurally, bottom-up:
//!
//! * a source is keyed by its [`MatrixSpec`] parameters
//!   `(n, block_size, seed, generator)` — generation is
//!   seed-deterministic, so equal keys denote bit-identical matrices;
//! * an operator node is keyed by `(op, child node ids…, params)` —
//!   children are interned first, so id equality is value equality.
//!
//! Two jobs that both need `invert[spin](A)` therefore hold the *same*
//! `Arc`'d plan node: whichever job materializes first pays, the other
//! reuses.
//!
//! Source leaves are **lazy** ([`crate::plan::SourceSpec`]): interning a
//! source builds an O(1) descriptor node — no block is generated or read
//! at submit — and the key stays `(n, block_size, seed, generator)`, so
//! a lazy leaf interns exactly where the old eager leaf did (equal specs
//! share one node either way; store-backed leaves key on the directory
//! plus its current generation id, so a re-ingested store is a new key).
//!
//! Retention is bounded by live jobs: the cache holds only **weak**
//! references, so when the last handle to a plan drops, its nodes — and
//! any payloads memoized inside them — free naturally and the dead entry
//! is purged on the next lookup. (Value residency of *materialized*
//! values is governed separately by the session's
//! [`crate::plan::CacheManager`] LRU budget.) Node construction runs
//! **outside** the cache lock, with a re-check on insert so two racing
//! submitters of the same spec still converge on one node.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Mutex, Weak};

use crate::error::Result;
use crate::plan::{ExprNode, InvertOpts, MatExpr, SourceSpec};
use crate::util::plock;

use super::spec::MatrixSpec;

/// Structural identity of an interned node.
#[derive(Debug, Clone, Hash, PartialEq, Eq)]
enum PlanKey {
    Source {
        n: usize,
        block_size: usize,
        seed: u64,
        generator: &'static str,
    },
    StoreSource {
        dir: PathBuf,
        n: usize,
        block_size: usize,
        /// Store generation id — a re-ingested directory is a NEW key,
        /// so fresh submits never adopt a stale leaf recorded against
        /// the old bytes.
        store_id: Option<String>,
    },
    Invert {
        algo: String,
        /// Iterative-solver knobs (`tolerance` bit-pattern, `max_iters`).
        /// Part of the key: a job asking for a looser tolerance must NOT
        /// adopt another tenant's tighter (different-valued) inverse.
        opts: (Option<u64>, Option<usize>),
        child: u64,
    },
    Multiply {
        a: u64,
        b: u64,
    },
    Transpose {
        x: u64,
    },
}

/// Hit/miss/size counters for reports and tests. `entries` counts only
/// entries whose plans are still alive.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PlanCacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
}

#[derive(Default)]
struct Inner {
    map: HashMap<PlanKey, Weak<ExprNode>>,
    hits: u64,
    misses: u64,
}

/// Thread-safe interner of job plan subtrees (see module docs).
#[derive(Default)]
pub struct PlanCache {
    inner: Mutex<Inner>,
}

impl PlanCache {
    pub fn new() -> Self {
        PlanCache::default()
    }

    fn intern(
        &self,
        key: PlanKey,
        build: impl FnOnce() -> Result<MatExpr>,
    ) -> Result<MatExpr> {
        {
            let mut inner = plock(&self.inner);
            if let Some(hit) = inner.map.get(&key).and_then(MatExpr::upgrade) {
                inner.hits += 1;
                return Ok(hit);
            }
        }
        // Build with the lock RELEASED (node construction is O(1) now
        // that sources are lazy, but the discipline keeps any future
        // heavyweight constructor from stalling other tenants' submits),
        // with a re-check so racing submitters converge on one node.
        let candidate = build()?;
        let mut inner = plock(&self.inner);
        if let Some(hit) = inner.map.get(&key).and_then(MatExpr::upgrade) {
            // Raced with another submitter: adopt the winner's node so
            // both jobs share one plan (our duplicate descriptor is
            // discarded; the data is seed-deterministic either way).
            inner.hits += 1;
            return Ok(hit);
        }
        // Dead entries (all referencing jobs finished and dropped their
        // handles) are purged here, keeping retention bounded by live
        // plans. Operator keys over dead child ids can never hit again —
        // a rebuilt child gets a fresh node id.
        inner.map.retain(|_, node| node.strong_count() > 0);
        inner.misses += 1;
        inner.map.insert(key, MatExpr::downgrade(&candidate));
        Ok(candidate)
    }

    /// The interned **lazy** plan leaf for a described matrix: O(1) to
    /// build — blocks are produced per-partition on the workers at first
    /// materialization, never driver-side at submit. The key is the same
    /// `(n, block_size, seed, generator)` the eager leaves used, so lazy
    /// and eager eras intern identically and equal specs share one node.
    pub fn source(&self, spec: &MatrixSpec) -> Result<MatExpr> {
        // Lower first: for store-backed specs this reads the directory's
        // current generation id, which is part of the key — a re-ingested
        // store interns as a fresh leaf instead of adopting a stale one.
        let source = spec.to_source_spec()?;
        let key = match &source {
            SourceSpec::Store {
                dir,
                nblocks,
                block_size,
                store_id,
            } => PlanKey::StoreSource {
                dir: dir.clone(),
                n: nblocks * block_size,
                block_size: *block_size,
                store_id: store_id.clone(),
            },
            SourceSpec::Generated { .. } => PlanKey::Source {
                n: spec.n,
                block_size: spec.block_size,
                seed: spec.seed,
                generator: spec.generator.name(),
            },
        };
        self.intern(key, || MatExpr::lazy_source(source))
    }

    /// Interned `child⁻¹` through the named scheme, with the job's
    /// iterative-solver knobs baked into both node and key.
    pub fn invert(&self, child: &MatExpr, algo: &str, opts: InvertOpts) -> Result<MatExpr> {
        self.intern(
            PlanKey::Invert {
                algo: algo.to_string(),
                opts: opts.key(),
                child: child.id(),
            },
            || Ok(child.invert_opts(algo, opts)),
        )
    }

    /// Interned `a·b`.
    pub fn multiply(&self, a: &MatExpr, b: &MatExpr) -> Result<MatExpr> {
        self.intern(
            PlanKey::Multiply {
                a: a.id(),
                b: b.id(),
            },
            || a.multiply(b),
        )
    }

    /// Interned `xᵀ`.
    pub fn transpose(&self, x: &MatExpr) -> Result<MatExpr> {
        self.intern(PlanKey::Transpose { x: x.id() }, || Ok(x.transpose()))
    }

    pub fn stats(&self) -> PlanCacheStats {
        let mut inner = plock(&self.inner);
        inner.map.retain(|_, node| node.strong_count() > 0);
        PlanCacheStats {
            hits: inner.hits,
            misses: inner.misses,
            entries: inner.map.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_specs_intern_to_one_source() {
        let cache = PlanCache::new();
        let spec = MatrixSpec::new(16, 4).seeded(3);
        let a = cache.source(&spec).unwrap();
        let b = cache.source(&MatrixSpec::new(16, 4).seeded(3)).unwrap();
        assert_eq!(a.id(), b.id(), "same spec must share one node");
        // A different seed is a different matrix.
        let c = cache.source(&spec.clone().seeded(4)).unwrap();
        assert_ne!(a.id(), c.id());
        let stats = cache.stats();
        assert_eq!(stats.hits, 1);
        assert_eq!(stats.misses, 2);
        assert_eq!(stats.entries, 2);
    }

    #[test]
    fn operators_intern_structurally() {
        let cache = PlanCache::new();
        let a = cache.source(&MatrixSpec::new(16, 4).seeded(1)).unwrap();
        let b = cache.source(&MatrixSpec::new(16, 4).seeded(2)).unwrap();
        let inv1 = cache.invert(&a, "spin", InvertOpts::default()).unwrap();
        let inv2 = cache.invert(&a, "spin", InvertOpts::default()).unwrap();
        assert_eq!(inv1.id(), inv2.id());
        assert_ne!(
            cache.invert(&a, "lu", InvertOpts::default()).unwrap().id(),
            inv1.id()
        );
        // Iterative knobs are part of the identity: a looser-tolerance
        // newton inverse is a different value, so a different node.
        let strict = cache
            .invert(
                &a,
                "newton",
                InvertOpts {
                    tolerance: Some(1e-10),
                    max_iters: None,
                },
            )
            .unwrap();
        let loose = cache
            .invert(
                &a,
                "newton",
                InvertOpts {
                    tolerance: Some(1e-4),
                    max_iters: None,
                },
            )
            .unwrap();
        assert_ne!(strict.id(), loose.id());
        assert_eq!(
            cache
                .invert(
                    &a,
                    "newton",
                    InvertOpts {
                        tolerance: Some(1e-10),
                        max_iters: None,
                    },
                )
                .unwrap()
                .id(),
            strict.id()
        );
        let m1 = cache.multiply(&inv1, &b).unwrap();
        let m2 = cache.multiply(&inv2, &b).unwrap();
        assert_eq!(m1.id(), m2.id(), "solve tails built twice share");
        // Operand order matters.
        assert_ne!(cache.multiply(&b, &inv1).unwrap().id(), m1.id());
        let t1 = cache.transpose(&a).unwrap();
        let t2 = cache.transpose(&a).unwrap();
        assert_eq!(t1.id(), t2.id());
    }

    #[test]
    fn sources_intern_lazy_with_no_driver_side_blocks() {
        let cache = PlanCache::new();
        let leaf = cache.source(&MatrixSpec::new(1 << 14, 1 << 7)).unwrap();
        // A 16384² matrix leaf: O(1) descriptor, nothing materialized.
        assert_eq!(leaf.op().name(), "lazy_source");
        assert!(leaf.cached_value().is_none());
        // Store-backed and generated specs of the same geometry are
        // DIFFERENT keys (different data). Only meta.json exists — no
        // block is touched by interning.
        let dir = std::env::temp_dir().join(format!("spin_cache_store_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        crate::store::LocalDirStore::create(&dir, 4, 8).unwrap();
        let gen32 = cache.source(&MatrixSpec::new(32, 8)).unwrap();
        let store_spec = MatrixSpec::from_store(&dir).unwrap();
        let store_leaf = cache.source(&store_spec).unwrap();
        assert_ne!(store_leaf.id(), gen32.id());
        // Same store path interns to one node.
        assert_eq!(cache.source(&store_spec).unwrap().id(), store_leaf.id());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn grid_mismatch_surfaces_from_constructor() {
        let cache = PlanCache::new();
        let a = cache.source(&MatrixSpec::new(16, 4)).unwrap();
        let b = cache.source(&MatrixSpec::new(16, 8)).unwrap();
        assert!(cache.multiply(&a, &b).is_err());
    }

    #[test]
    fn dead_plans_are_released_not_pinned() {
        let cache = PlanCache::new();
        let spec = MatrixSpec::new(16, 4).seeded(9);
        {
            let a = cache.source(&spec).unwrap();
            let _inv = cache.invert(&a, "spin", InvertOpts::default()).unwrap();
            assert_eq!(cache.stats().entries, 2);
        } // last handles drop: payloads free, entries purge
        assert_eq!(
            cache.stats().entries,
            0,
            "weak interning must not pin dead plans' payloads"
        );
        // A re-lookup regenerates: a fresh miss, a fresh node.
        let again = cache.source(&spec).unwrap();
        assert_eq!(cache.stats().misses, 3);
        assert_eq!(cache.stats().entries, 1);
        drop(again);
    }
}
