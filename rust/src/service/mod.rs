//! [`SpinService`]: an async, multi-tenant job layer over the session
//! stack — the service-shaped front door the ROADMAP's "heavy traffic
//! from many users" north star asks for.
//!
//! Callers [`submit`](SpinService::submit) workloads described by a
//! serializable [`JobSpec`] (invert / solve / multiply / pseudo-inverse
//! over parameter-described matrices) and get back a [`JobHandle`]:
//! poll it ([`status`](JobHandle::status)), block on it
//! ([`wait`](JobHandle::wait)), cancel it while queued
//! ([`cancel`](JobHandle::cancel)), and introspect it (per-job
//! [`metrics`](JobHandle::metrics) via cluster metric scopes,
//! [`explain`](JobHandle::explain) for the optimized plan).
//!
//! Three pieces make concurrent jobs cheap and safe:
//!
//! * a **fair-share scheduler**: a bounded queue bucketed per tenant and
//!   drained round-robin, so one chatty tenant cannot starve the rest,
//!   and saturation surfaces as a `submit` error (backpressure) rather
//!   than unbounded memory;
//! * a **cross-job plan cache** ([`PlanCache`]): structural interning of
//!   plan subtrees, so two jobs needing `invert[spin](A)` hold the same
//!   `Arc`'d node — the executor's memo-slot locking then guarantees the
//!   shared work runs exactly once no matter which worker gets there
//!   first;
//! * the **value lifecycle** ([`crate::plan::CacheManager`]): every
//!   materialized value is tracked and the session's
//!   `cache_budget_bytes` LRU evictor bounds the resident set across all
//!   jobs; evicted values recompute bit-identically on the next read.
//!
//! ```no_run
//! use spin::service::{JobSpec, MatrixSpec, SpinService};
//!
//! fn main() -> spin::Result<()> {
//!     let service = SpinService::builder().cores(4).workers(2).build()?;
//!     let a = MatrixSpec::new(256, 64).seeded(7);
//!     let inv = service.submit(JobSpec::invert(a.clone()).tenant("alice"))?;
//!     let sol = service.submit(
//!         JobSpec::solve(a, MatrixSpec::new(256, 64).seeded(8)).tenant("bob"),
//!     )?;
//!     // Both jobs share the interned invert[spin](A) node: it executes once.
//!     let inv_out = inv.wait()?;
//!     let sol_out = sol.wait()?;
//!     println!("residual {:?}", inv_out.residual);
//!     println!("solve paid {} exchanges", sol_out.metrics.total_shuffle_stages());
//!     Ok(())
//! }
//! ```

mod cache;
mod scheduler;
mod spec;

pub use cache::{PlanCache, PlanCacheStats};
pub use spec::{JobKind, JobSpec, MatrixSpec};

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

use crate::cluster::{Metrics, MetricsSnapshot};
use crate::config::ClusterConfig;
use crate::error::{Result, SpinError};
use crate::linalg::{inverse_residual, Matrix};
use crate::plan::{CacheStats, MatExpr};
use crate::session::{SessionBuilder, SpinSession};

use scheduler::FairShareQueue;

/// Where a job is in its life.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobStatus {
    Queued,
    Running,
    Completed,
    Failed,
    Cancelled,
}

/// What a finished job produced.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    /// The result matrix, assembled dense on the driver.
    pub dense: Matrix,
    /// ‖A·X − I‖-style inversion residual, for kinds that invert the
    /// job's primary matrix (`Invert`, `PseudoInverse`).
    pub residual: Option<f64>,
    /// Everything THIS job's execution recorded on the shared cluster
    /// (scoped by job id — concurrent jobs never pollute each other).
    pub metrics: MetricsSnapshot,
}

enum Phase {
    Queued,
    Running,
    Cancelled,
    Completed(JobOutcome),
    Failed(String),
}

struct JobState {
    id: u64,
    spec: JobSpec,
    /// The interned result plan (shared with other jobs where structure
    /// allows).
    expr: MatExpr,
    /// The job's primary input, kept for the residual check.
    residual_source: Option<MatExpr>,
    phase: Mutex<Phase>,
    cv: Condvar,
}

/// Cheap, clonable reference to one submitted job.
#[derive(Clone)]
pub struct JobHandle {
    state: Arc<JobState>,
    inner: Arc<ServiceInner>,
}

impl JobHandle {
    /// Service-unique job id (also the job's metrics scope tag).
    pub fn id(&self) -> u64 {
        self.state.id
    }

    /// The spec this job was submitted with.
    pub fn spec(&self) -> &JobSpec {
        &self.state.spec
    }

    pub fn status(&self) -> JobStatus {
        match &*self.state.phase.lock().unwrap() {
            Phase::Queued => JobStatus::Queued,
            Phase::Running => JobStatus::Running,
            Phase::Cancelled => JobStatus::Cancelled,
            Phase::Completed(_) => JobStatus::Completed,
            Phase::Failed(_) => JobStatus::Failed,
        }
    }

    /// Block until the job reaches a terminal state.
    pub fn wait(&self) -> Result<JobOutcome> {
        let mut phase = self.state.phase.lock().unwrap();
        loop {
            match &*phase {
                Phase::Completed(outcome) => return Ok(outcome.clone()),
                Phase::Failed(msg) => {
                    return Err(SpinError::cluster(format!(
                        "job {} failed: {msg}",
                        self.state.id
                    )));
                }
                Phase::Cancelled => {
                    return Err(SpinError::cluster(format!(
                        "job {} was cancelled",
                        self.state.id
                    )));
                }
                Phase::Queued | Phase::Running => {
                    phase = self.state.cv.wait(phase).unwrap();
                }
            }
        }
    }

    /// Cancel a still-queued job. Returns `true` if the cancellation took
    /// effect; a running or finished job is not interrupted (`false`).
    /// The queue slot frees immediately, so cancelling relieves
    /// backpressure.
    pub fn cancel(&self) -> bool {
        {
            let mut phase = self.state.phase.lock().unwrap();
            if !matches!(*phase, Phase::Queued) {
                return false;
            }
            *phase = Phase::Cancelled;
            self.state.cv.notify_all();
        }
        // Remove our queue entry (a worker may have popped it already —
        // then run_job sees Cancelled and skips; either way the phase is
        // terminal and the slot is free).
        let id = self.state.id;
        self.inner
            .queue
            .lock()
            .unwrap()
            .remove_where(&self.state.spec.tenant, |job| job.id == id);
        true
    }

    /// Live per-job metrics window (empty until the job starts running).
    pub fn metrics(&self) -> MetricsSnapshot {
        self.inner.session.cluster().metrics_scoped(self.state.id)
    }

    /// Render this job's optimized plan — fusions, CSE cache points,
    /// predicted shuffle stages, and cache decisions per node.
    pub fn explain(&self) -> Result<String> {
        self.inner.session.explain_expr(&self.state.expr)
    }
}

struct ServiceInner {
    session: SpinSession,
    plans: PlanCache,
    queue: Mutex<FairShareQueue<Arc<JobState>>>,
    work_cv: Condvar,
    shutdown: AtomicBool,
    next_job: AtomicU64,
}

impl ServiceInner {
    fn submit(self: &Arc<Self>, spec: JobSpec) -> Result<JobHandle> {
        if self.shutdown.load(Ordering::SeqCst) {
            return Err(SpinError::cluster("service is shutting down"));
        }
        for matrix in spec.matrices() {
            matrix.validate()?;
        }
        // Resolve the scheme now: an unknown name must fail at submit,
        // not minutes later on a worker thread.
        let algo = spec
            .algo
            .clone()
            .unwrap_or_else(|| self.session.default_algorithm().to_string());
        self.session.registry().get(&algo)?;
        let (expr, residual_source) = self.build_plan(&spec, &algo)?;
        // Ids start at 1: scope 0 stays the ambient (non-job) scope.
        let id = self.next_job.fetch_add(1, Ordering::Relaxed) + 1;
        let state = Arc::new(JobState {
            id,
            spec,
            expr,
            residual_source,
            phase: Mutex::new(Phase::Queued),
            cv: Condvar::new(),
        });
        self.queue
            .lock()
            .unwrap()
            .push(&state.spec.tenant, Arc::clone(&state))?;
        self.work_cv.notify_one();
        Ok(JobHandle {
            state,
            inner: Arc::clone(self),
        })
    }

    /// Lower a spec onto interned plan nodes (the cross-job sharing
    /// point: equal sub-structure → same `Arc`'d node).
    fn build_plan(&self, spec: &JobSpec, algo: &str) -> Result<(MatExpr, Option<MatExpr>)> {
        match &spec.kind {
            JobKind::Invert { matrix } => {
                let src = self.plans.source(matrix)?;
                Ok((self.plans.invert(&src, algo)?, Some(src)))
            }
            JobKind::Solve { matrix, rhs } => {
                let a = self.plans.source(matrix)?;
                let b = self.plans.source(rhs)?;
                let inv = self.plans.invert(&a, algo)?;
                Ok((self.plans.multiply(&inv, &b)?, None))
            }
            JobKind::Multiply { a, b } => {
                let ea = self.plans.source(a)?;
                let eb = self.plans.source(b)?;
                Ok((self.plans.multiply(&ea, &eb)?, None))
            }
            JobKind::PseudoInverse { matrix } => {
                let m = self.plans.source(matrix)?;
                let mt = self.plans.transpose(&m)?;
                let gram = self.plans.multiply(&mt, &m)?;
                let gram_inv = self.plans.invert(&gram, algo)?;
                Ok((self.plans.multiply(&gram_inv, &mt)?, Some(m)))
            }
        }
    }

    /// Execute one popped job on the calling thread.
    fn run_job(&self, job: &Arc<JobState>) {
        {
            let mut phase = job.phase.lock().unwrap();
            if !matches!(*phase, Phase::Queued) {
                // Cancelled while queued: skip silently.
                return;
            }
            *phase = Phase::Running;
        }
        // Everything this job records on the shared cluster is tagged
        // with its id, so per-job windows stay exact under concurrency.
        let _scope = Metrics::enter_scope(job.id);
        let outcome = self.execute(job);
        let mut phase = job.phase.lock().unwrap();
        *phase = match outcome {
            Ok(o) => Phase::Completed(o),
            Err(e) => Phase::Failed(e.to_string()),
        };
        job.cv.notify_all();
    }

    fn execute(&self, job: &JobState) -> Result<JobOutcome> {
        let result = self.session.materialize(&job.expr)?;
        let dense = result.to_dense()?;
        let residual = match &job.residual_source {
            Some(src) => {
                let src_dense = self.session.materialize(src)?.to_dense()?;
                Some(inverse_residual(&src_dense, &dense))
            }
            None => None,
        };
        Ok(JobOutcome {
            dense,
            residual,
            metrics: self.session.cluster().metrics_scoped(job.id),
        })
    }
}

fn worker_loop(inner: Arc<ServiceInner>) {
    loop {
        let job = {
            let mut queue = inner.queue.lock().unwrap();
            loop {
                if let Some(job) = queue.pop() {
                    break Some(job);
                }
                if inner.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                queue = inner.work_cv.wait(queue).unwrap();
            }
        };
        match job {
            Some(job) => inner.run_job(&job),
            None => return,
        }
    }
}

/// Builder for [`SpinService`].
pub struct ServiceBuilder {
    session: SessionBuilder,
    workers: usize,
    queue_capacity: usize,
}

impl Default for ServiceBuilder {
    fn default() -> Self {
        ServiceBuilder {
            session: SessionBuilder::default(),
            workers: 2,
            queue_capacity: 64,
        }
    }
}

impl ServiceBuilder {
    /// Replace the whole underlying session configuration.
    pub fn session_builder(mut self, session: SessionBuilder) -> Self {
        self.session = session;
        self
    }

    /// Local single-node cluster with `cores` task slots.
    pub fn cores(mut self, cores: usize) -> Self {
        self.session = self.session.cores(cores);
        self
    }

    /// Replace the cluster topology (including `cache_budget_bytes`).
    pub fn cluster_config(mut self, cfg: ClusterConfig) -> Self {
        self.session = self.session.cluster_config(cfg);
        self
    }

    /// Scheme used when a spec names none.
    pub fn default_algorithm(mut self, name: &str) -> Self {
        self.session = self.session.default_algorithm(name);
        self
    }

    /// Job-executor threads. `0` = no background execution: jobs queue
    /// until [`SpinService::run_pending`] drains them on the caller's
    /// thread (deterministic tests, batch drivers).
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    /// Bound on queued (not yet running) jobs across all tenants.
    pub fn queue_capacity(mut self, capacity: usize) -> Self {
        self.queue_capacity = capacity;
        self
    }

    pub fn build(self) -> Result<SpinService> {
        let session = self.session.build()?;
        let inner = Arc::new(ServiceInner {
            session,
            plans: PlanCache::new(),
            queue: Mutex::new(FairShareQueue::new(self.queue_capacity)),
            work_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            next_job: AtomicU64::new(0),
        });
        let workers = (0..self.workers)
            .map(|i| {
                let inner = Arc::clone(&inner);
                thread::Builder::new()
                    .name(format!("spin-service-{i}"))
                    .spawn(move || worker_loop(inner))
                    .expect("spawn service worker thread")
            })
            .collect();
        Ok(SpinService { inner, workers })
    }
}

/// The job service: one shared session/cluster, a worker pool draining a
/// fair-share queue, a cross-job plan cache, and per-job introspection.
/// Dropping the service stops the workers; still-queued jobs are marked
/// cancelled (running jobs finish first — drop joins the workers).
pub struct SpinService {
    inner: Arc<ServiceInner>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl SpinService {
    pub fn builder() -> ServiceBuilder {
        ServiceBuilder::default()
    }

    /// Queue a job and return its handle. All *distributed* work runs
    /// asynchronously on the workers; what runs on the calling thread is
    /// validation plus the job's input **definition** — first use of a
    /// `MatrixSpec` generates its blocks here, so equal specs can intern
    /// to one shared plan leaf. (Lazy generator leaves — moving that cost
    /// onto the workers too — are noted future work in the ROADMAP.)
    /// Fails fast on bad geometry, unknown algorithms, or a saturated
    /// queue.
    pub fn submit(&self, spec: JobSpec) -> Result<JobHandle> {
        self.inner.submit(spec)
    }

    /// Run queued jobs on the calling thread until the queue is empty;
    /// returns how many ran. The synchronous driver for `workers(0)`
    /// services (batch replay, deterministic tests); safe alongside
    /// background workers too.
    pub fn run_pending(&self) -> usize {
        let mut ran = 0;
        loop {
            let job = self.inner.queue.lock().unwrap().pop();
            match job {
                Some(job) => {
                    self.inner.run_job(&job);
                    ran += 1;
                }
                None => return ran,
            }
        }
    }

    /// The shared session every job executes on.
    pub fn session(&self) -> &SpinSession {
        &self.inner.session
    }

    /// Cross-job plan-cache counters.
    pub fn plan_cache_stats(&self) -> PlanCacheStats {
        self.inner.plans.stats()
    }

    /// Value-lifecycle counters (resident bytes, budget, evictions).
    pub fn cache_stats(&self) -> CacheStats {
        self.inner.session.cache_stats()
    }

    /// Cluster-global metrics across all jobs.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.inner.session.metrics()
    }

    /// Jobs queued and not yet picked up.
    pub fn queued_jobs(&self) -> usize {
        self.inner.queue.lock().unwrap().len()
    }

    /// Background worker threads.
    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for SpinService {
    fn drop(&mut self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        // Abandon still-queued jobs so their waiters unblock.
        let abandoned = self.inner.queue.lock().unwrap().drain();
        for job in abandoned {
            let mut phase = job.phase.lock().unwrap();
            if matches!(*phase, Phase::Queued) {
                *phase = Phase::Cancelled;
            }
            drop(phase);
            job.cv.notify_all();
        }
        self.inner.work_cv.notify_all();
        for worker in self.workers.drain(..) {
            let _ = worker.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sync_service() -> SpinService {
        SpinService::builder().cores(2).workers(0).build().unwrap()
    }

    #[test]
    fn submit_wait_invert_matches_session() {
        let service = SpinService::builder().cores(2).workers(1).build().unwrap();
        let handle = service
            .submit(JobSpec::invert(MatrixSpec::new(32, 8).seeded(5)).label("inv"))
            .unwrap();
        let outcome = handle.wait().unwrap();
        assert_eq!(handle.status(), JobStatus::Completed);
        assert!(outcome.residual.unwrap() < 1e-9);
        assert!(outcome.metrics.method("multiply").is_some());
        assert_eq!(outcome.metrics.driver_collects(), 0);
        // Reference: the same inversion through a plain session.
        let session = SpinSession::local(2).unwrap();
        let a = session.random_seeded(32, 8, 5).unwrap();
        let want = a.inverse().unwrap().to_dense().unwrap();
        assert_eq!(outcome.dense.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn submit_validates_before_queueing() {
        let service = sync_service();
        // Bad geometry.
        let err = service
            .submit(JobSpec::invert(MatrixSpec::new(100, 10)))
            .unwrap_err();
        assert!(err.to_string().contains("power of two"), "{err}");
        // Unknown algorithm.
        let err = service
            .submit(JobSpec::invert(MatrixSpec::new(16, 4)).algorithm("qr"))
            .unwrap_err();
        assert!(err.to_string().contains("unknown algorithm"), "{err}");
        // Grid mismatch inside a binary kind.
        let err = service
            .submit(JobSpec::multiply(
                MatrixSpec::new(16, 4),
                MatrixSpec::new(16, 8),
            ))
            .unwrap_err();
        assert!(err.to_string().contains("grid mismatch"), "{err}");
        assert_eq!(service.queued_jobs(), 0, "nothing bad was queued");
    }

    #[test]
    fn queue_capacity_backpressure_and_cancel() {
        let service = SpinService::builder()
            .cores(2)
            .workers(0)
            .queue_capacity(2)
            .build()
            .unwrap();
        let spec = || JobSpec::invert(MatrixSpec::new(16, 4));
        let h1 = service.submit(spec()).unwrap();
        let h2 = service.submit(spec().tenant("other")).unwrap();
        let err = service.submit(spec()).unwrap_err();
        assert!(err.to_string().contains("queue is full"), "{err}");
        // Cancelling a queued job frees its slot immediately.
        assert!(h2.cancel());
        assert!(!h2.cancel(), "second cancel is a no-op");
        assert_eq!(h2.status(), JobStatus::Cancelled);
        assert!(h2.wait().unwrap_err().to_string().contains("cancelled"));
        assert_eq!(service.queued_jobs(), 1, "cancel must relieve backpressure");
        let h3 = service.submit(spec().tenant("third")).unwrap();
        assert_eq!(service.run_pending(), 2, "h1 and h3 run; h2 never pops");
        assert_eq!(h1.status(), JobStatus::Completed);
        assert_eq!(h3.status(), JobStatus::Completed);
        // A completed job cannot be cancelled.
        assert!(!h1.cancel());
    }

    #[test]
    fn shared_subexpression_executes_once_across_jobs() {
        let service = sync_service();
        let a = MatrixSpec::new(64, 16).seeded(0xA);
        let b = MatrixSpec::new(64, 16).seeded(0xB);
        let inv = service.submit(JobSpec::invert(a.clone())).unwrap();
        let solve = service.submit(JobSpec::solve(a, b)).unwrap();
        assert_eq!(service.run_pending(), 2);
        let inv_out = inv.wait().unwrap();
        let solve_out = solve.wait().unwrap();
        assert!(inv_out.residual.unwrap() < 1e-9);
        assert!(solve_out.residual.is_none());
        // The invert[spin](A) node is interned once, so across BOTH jobs
        // the recursion's leaves ran exactly once: grid 4 → 4 leaf calls.
        let total = service.metrics();
        assert_eq!(total.method("leafNode").unwrap().calls, 4);
        // Plan cache saw the share: the solve's invert lookup was a hit.
        let stats = service.plan_cache_stats();
        assert!(stats.hits >= 2, "source + invert re-lookups hit: {stats:?}");
        // Per-job attribution: the solve job paid the inversion (it ran
        // second only in submission order — the scheduler interleaves
        // tenants, but here both are `default`), while the other job got
        // the memoized value. Exactly one job carries the leaf stages.
        let inv_leaves = inv_out
            .metrics
            .method("leafNode")
            .map(|s| s.calls)
            .unwrap_or(0);
        let solve_leaves = solve_out
            .metrics
            .method("leafNode")
            .map(|s| s.calls)
            .unwrap_or(0);
        assert_eq!(inv_leaves + solve_leaves, 4);
    }

    #[test]
    fn per_job_metrics_are_scoped() {
        let service = sync_service();
        let h1 = service
            .submit(JobSpec::multiply(
                MatrixSpec::new(16, 4).seeded(1),
                MatrixSpec::new(16, 4).seeded(2),
            ))
            .unwrap();
        let h2 = service
            .submit(JobSpec::multiply(
                MatrixSpec::new(16, 4).seeded(3),
                MatrixSpec::new(16, 4).seeded(4),
            ))
            .unwrap();
        service.run_pending();
        let m1 = h1.wait().unwrap().metrics;
        let m2 = h2.wait().unwrap().metrics;
        // Each distinct multiply pays its own single shuffle round (2
        // exchange stages) — and ONLY its own.
        assert_eq!(m1.method("multiply").unwrap().shuffle_stages, 2);
        assert_eq!(m2.method("multiply").unwrap().shuffle_stages, 2);
        assert_eq!(service.metrics().total_shuffle_stages(), 4);
        // The live handle view agrees with the outcome snapshot.
        assert_eq!(h1.metrics().total_shuffle_stages(), 2);
    }

    #[test]
    fn pseudo_inverse_job_and_explain() {
        let service = sync_service();
        let handle = service
            .submit(JobSpec::pseudo_inverse(MatrixSpec::new(32, 8).seeded(9).spd()))
            .unwrap();
        // explain works while the job is still queued.
        let text = handle.explain().unwrap();
        assert!(text.contains("invert[spin]"), "{text}");
        assert!(text.contains("transpose"), "{text}");
        service.run_pending();
        let out = handle.wait().unwrap();
        assert!(out.residual.unwrap() < 1e-8);
    }

    #[test]
    fn failed_job_reports_error() {
        use crate::algos::InversionAlgorithm;
        use crate::blockmatrix::BlockMatrix;
        use crate::cluster::Cluster;
        use crate::config::JobConfig;
        use crate::runtime::BlockKernels;

        struct Exploding;
        impl InversionAlgorithm for Exploding {
            fn name(&self) -> &str {
                "exploding"
            }
            fn invert(
                &self,
                _cluster: &Cluster,
                _kernels: &dyn BlockKernels,
                _a: &BlockMatrix,
                _job: &JobConfig,
            ) -> Result<BlockMatrix> {
                Err(SpinError::numerical("boom"))
            }
        }
        let service = SpinService::builder()
            .session_builder(
                SpinSession::builder()
                    .cores(2)
                    .register_algorithm(Arc::new(Exploding))
                    .unwrap(),
            )
            .workers(0)
            .build()
            .unwrap();
        let h = service
            .submit(JobSpec::invert(MatrixSpec::new(16, 4)).algorithm("exploding"))
            .unwrap();
        service.run_pending();
        assert_eq!(h.status(), JobStatus::Failed);
        let err = h.wait().unwrap_err().to_string();
        assert!(err.contains("failed") && err.contains("boom"), "{err}");
        // A failed job cannot be cancelled after the fact.
        assert!(!h.cancel());
    }

    #[test]
    fn fair_share_run_order_across_tenants() {
        let service = sync_service();
        let spec = |seed: u64, tenant: &str| {
            JobSpec::multiply(
                MatrixSpec::new(16, 4).seeded(seed),
                MatrixSpec::new(16, 4).seeded(seed + 100),
            )
            .tenant(tenant)
        };
        let a1 = service.submit(spec(1, "alice")).unwrap();
        let a2 = service.submit(spec(2, "alice")).unwrap();
        let b1 = service.submit(spec(3, "bob")).unwrap();
        // Synchronous drain pops in fair-share order: alice, bob, alice.
        // Job ids are submission-ordered, so check scope stage ordering
        // via the global stage stream: run one job at a time.
        assert_eq!(service.queued_jobs(), 3);
        let first = {
            let job = service.inner.queue.lock().unwrap().pop().unwrap();
            let id = job.id;
            service.inner.run_job(&job);
            id
        };
        let second = {
            let job = service.inner.queue.lock().unwrap().pop().unwrap();
            let id = job.id;
            service.inner.run_job(&job);
            id
        };
        assert_eq!(first, a1.id());
        assert_eq!(second, b1.id(), "bob's turn before alice's backlog");
        service.run_pending();
        assert_eq!(a2.status(), JobStatus::Completed);
        for h in [a1, a2, b1] {
            h.wait().unwrap();
        }
    }
}
