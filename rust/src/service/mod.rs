//! [`SpinService`]: an async, multi-tenant job layer over the session
//! stack — the service-shaped front door the ROADMAP's "heavy traffic
//! from many users" north star asks for.
//!
//! Callers [`submit`](SpinService::submit) workloads described by a
//! serializable [`JobSpec`] (invert / solve / multiply / pseudo-inverse
//! over parameter-described matrices) and get back a [`JobHandle`]:
//! poll it ([`status`](JobHandle::status)), block on it
//! ([`wait`](JobHandle::wait)), cancel it while queued
//! ([`cancel`](JobHandle::cancel)), and introspect it (per-job
//! [`metrics`](JobHandle::metrics) via cluster metric scopes,
//! [`explain`](JobHandle::explain) for the optimized plan).
//!
//! Five pieces make a long-lived, concurrent service cheap and safe:
//!
//! * **O(1) submit**: matrix inputs are *described*, not materialized —
//!   a `MatrixSpec` lowers to a lazy [`crate::plan::SourceSpec`] leaf
//!   whose blocks are generated (or loaded from a
//!   [`crate::store::BlockStore`]) per-partition **on the workers** at
//!   first materialization, so `submit()` returns without touching a
//!   single block;
//! * a **fair-share scheduler**: a bounded queue bucketed per tenant and
//!   drained round-robin, so one chatty tenant cannot starve the rest,
//!   and saturation surfaces as a `submit` error (backpressure) rather
//!   than unbounded memory;
//! * a **cross-job plan cache** ([`PlanCache`]): structural interning of
//!   plan subtrees, so two jobs needing `invert[spin](A)` hold the same
//!   `Arc`'d node — the executor's memo-slot locking then guarantees the
//!   shared work runs exactly once no matter which worker gets there
//!   first;
//! * the **value lifecycle** ([`crate::plan::CacheManager`]): every
//!   materialized value — including lazily-born source values — is
//!   tracked and the session's `cache_budget_bytes` LRU evictor bounds
//!   the resident set across all jobs; evicted values recompute
//!   bit-identically on the next read;
//! * **bounded metrics**: a finished job's metric scope is released
//!   (stage records, plan-node reports, index) the moment it reaches a
//!   terminal phase — its full snapshot lives on in
//!   [`JobOutcome::metrics`] — and `--set metrics_history=N` additionally
//!   windows whatever remains, so `spin serve` holds steady-state memory
//!   across any number of jobs. Failures are contained: a panicking
//!   generator or algorithm fails *its* job (`Failed`, with the panic
//!   message) while the workers, locks, and queue keep serving.
//!
//! The service also exposes the seams the HTTP front door
//! ([`crate::http`]) builds on: a phase-transition **event bus**
//! ([`SpinService::subscribe`], [`JobHandle::history`]) publishing
//! `queued → running → completed/failed/cancelled` with timestamps, an
//! **id-stable submit** ([`SpinService::submit_with_id`], idempotent by
//! job id), and an optional **durable job log**
//! ([`ServiceBuilder::job_log`]) that fsyncs every submit and terminal
//! before it becomes visible, so a restarted server resumes exactly the
//! jobs that were in flight.
//!
//! ```no_run
//! use spin::service::{JobSpec, MatrixSpec, SpinService};
//!
//! fn main() -> spin::Result<()> {
//!     let service = SpinService::builder().cores(4).workers(2).build()?;
//!     let a = MatrixSpec::new(256, 64).seeded(7);
//!     let inv = service.submit(JobSpec::invert(a.clone()).tenant("alice"))?;
//!     let sol = service.submit(
//!         JobSpec::solve(a, MatrixSpec::new(256, 64).seeded(8)).tenant("bob"),
//!     )?;
//!     // Both jobs share the interned invert[spin](A) node: it executes once.
//!     let inv_out = inv.wait()?;
//!     let sol_out = sol.wait()?;
//!     println!("residual {:?}", inv_out.residual);
//!     println!("solve paid {} exchanges", sol_out.metrics.total_shuffle_stages());
//!     Ok(())
//! }
//! ```

mod cache;
mod scheduler;
mod spec;

pub use cache::{PlanCache, PlanCacheStats};
pub use spec::{JobKind, JobSpec, MatrixSpec};

use std::collections::BTreeMap;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;

use crate::cluster::{Metrics, MetricsSnapshot};
use crate::config::ClusterConfig;
use crate::error::{Result, SpinError};
use crate::linalg::{inverse_residual, Matrix};
use crate::plan::{CacheStats, MatExpr};
use crate::session::{SessionBuilder, SpinSession};
use crate::store::checkpoint;
use crate::store::joblog::{CheckpointRecord, JobLog};
use crate::util::{now_ms, plock, pwait};

use scheduler::FairShareQueue;

/// Human-readable payload of a caught job panic.
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Where a job is in its life.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobStatus {
    Queued,
    Running,
    Completed,
    Failed,
    Cancelled,
}

impl JobStatus {
    /// Stable wire name — HTTP status JSON, SSE events, job-log records.
    pub fn name(&self) -> &'static str {
        match self {
            JobStatus::Queued => "queued",
            JobStatus::Running => "running",
            JobStatus::Completed => "completed",
            JobStatus::Failed => "failed",
            JobStatus::Cancelled => "cancelled",
        }
    }

    /// Inverse of [`name`](JobStatus::name) (job-log replay).
    pub fn parse(s: &str) -> Result<JobStatus> {
        Ok(match s {
            "queued" => JobStatus::Queued,
            "running" => JobStatus::Running,
            "completed" => JobStatus::Completed,
            "failed" => JobStatus::Failed,
            "cancelled" => JobStatus::Cancelled,
            other => {
                return Err(SpinError::config(format!("unknown job status `{other}`")));
            }
        })
    }

    /// Completed, failed or cancelled — the phases a job never leaves.
    pub fn is_terminal(&self) -> bool {
        !matches!(self, JobStatus::Queued | JobStatus::Running)
    }
}

/// One phase transition as published on the service event bus — what
/// [`JobHandle::history`] records and the HTTP layer streams as SSE.
#[derive(Debug, Clone)]
pub struct JobEvent {
    /// Global publication order, strictly increasing across the service.
    /// Subscribers that merge a history snapshot with a live feed dedup
    /// on this.
    pub seq: u64,
    pub job_id: u64,
    pub status: JobStatus,
    /// Wall-clock transition time, milliseconds since the Unix epoch.
    pub ts_ms: u64,
}

/// Terminal outcome in summary form: what the status endpoint reports
/// and what survives a restart for jobs recovered from the job log.
#[derive(Debug, Clone)]
pub struct TerminalSummary {
    pub status: JobStatus,
    pub error: Option<String>,
    pub residual: Option<f64>,
}

/// What a finished job produced.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    /// The result matrix, assembled dense on the driver.
    pub dense: Matrix,
    /// ‖A·X − I‖-style inversion residual, for kinds that invert the
    /// job's primary matrix (`Invert`, `PseudoInverse`).
    pub residual: Option<f64>,
    /// Everything THIS job's execution recorded on the shared cluster
    /// (scoped by job id — concurrent jobs never pollute each other).
    pub metrics: MetricsSnapshot,
}

enum Phase {
    Queued,
    Running,
    Cancelled,
    Completed(JobOutcome),
    Failed(String),
}

fn phase_status(phase: &Phase) -> JobStatus {
    match phase {
        Phase::Queued => JobStatus::Queued,
        Phase::Running => JobStatus::Running,
        Phase::Cancelled => JobStatus::Cancelled,
        Phase::Completed(_) => JobStatus::Completed,
        Phase::Failed(_) => JobStatus::Failed,
    }
}

struct JobState {
    id: u64,
    spec: JobSpec,
    /// The interned result plan (shared with other jobs where structure
    /// allows).
    expr: MatExpr,
    /// The job's primary input, kept for the residual check.
    residual_source: Option<MatExpr>,
    phase: Mutex<Phase>,
    cv: Condvar,
    /// Phase transitions in publication order (see [`JobEvent`]).
    history: Mutex<Vec<JobEvent>>,
}

/// Cheap, clonable reference to one submitted job.
#[derive(Clone)]
pub struct JobHandle {
    state: Arc<JobState>,
    inner: Arc<ServiceInner>,
}

impl JobHandle {
    /// Service-unique job id (also the job's metrics scope tag).
    pub fn id(&self) -> u64 {
        self.state.id
    }

    /// The spec this job was submitted with.
    pub fn spec(&self) -> &JobSpec {
        &self.state.spec
    }

    pub fn status(&self) -> JobStatus {
        phase_status(&plock(&self.state.phase))
    }

    /// Phase-transition history so far, oldest first.
    pub fn history(&self) -> Vec<JobEvent> {
        plock(&self.state.history).clone()
    }

    /// The outcome, once the job has completed (`None` otherwise).
    pub fn outcome(&self) -> Option<JobOutcome> {
        match &*plock(&self.state.phase) {
            Phase::Completed(o) => Some(o.clone()),
            _ => None,
        }
    }

    /// Terminal summary (status + error + residual) once the job has
    /// reached a terminal phase (`None` while queued/running).
    pub fn terminal(&self) -> Option<TerminalSummary> {
        match &*plock(&self.state.phase) {
            Phase::Completed(o) => Some(TerminalSummary {
                status: JobStatus::Completed,
                error: None,
                residual: o.residual,
            }),
            Phase::Failed(msg) => Some(TerminalSummary {
                status: JobStatus::Failed,
                error: Some(msg.clone()),
                residual: None,
            }),
            Phase::Cancelled => Some(TerminalSummary {
                status: JobStatus::Cancelled,
                error: None,
                residual: None,
            }),
            Phase::Queued | Phase::Running => None,
        }
    }

    /// Block until the job reaches a terminal state.
    pub fn wait(&self) -> Result<JobOutcome> {
        let mut phase = plock(&self.state.phase);
        loop {
            match &*phase {
                Phase::Completed(outcome) => return Ok(outcome.clone()),
                Phase::Failed(msg) => {
                    return Err(SpinError::cluster(format!(
                        "job {} failed: {msg}",
                        self.state.id
                    )));
                }
                Phase::Cancelled => {
                    return Err(SpinError::cluster(format!(
                        "job {} was cancelled",
                        self.state.id
                    )));
                }
                Phase::Queued | Phase::Running => {
                    phase = pwait(&self.state.cv, phase);
                }
            }
        }
    }

    /// Cancel a still-queued job. Returns `true` **iff** this call
    /// removed the job from the queue — and then the job never runs; a
    /// running or finished job is not interrupted (`false`). There is no
    /// in-between: workers claim a job's phase *under the queue lock*
    /// when they pop it, so a job is always either in the queue (and
    /// cancellable) or already claimed (and not). The freed slot relieves
    /// backpressure immediately.
    pub fn cancel(&self) -> bool {
        // Fast path: a phase never returns to Queued, so a job observed
        // claimed/terminal here can never be cancellable again — skip the
        // service-wide queue lock for late/polling cancellers.
        if !matches!(*plock(&self.state.phase), Phase::Queued) {
            return false;
        }
        let id = self.state.id;
        // Lock order queue → phase, matching the workers' pop+claim.
        let mut queue = plock(&self.inner.queue);
        let removed = queue
            .remove_where(&self.state.spec.tenant, |job| job.id == id)
            .is_some();
        if !removed {
            return false;
        }
        let mut phase = plock(&self.state.phase);
        debug_assert!(matches!(*phase, Phase::Queued), "queued jobs stay Queued");
        *phase = Phase::Cancelled;
        drop(phase);
        drop(queue);
        // An explicit cancel is a durable terminal: a restarted server
        // must not resurrect the job.
        self.inner
            .log_terminal(id, JobStatus::Cancelled, None, None);
        // A cancelled job's recovered checkpoints will never be used.
        if let Some(log) = &self.inner.job_log {
            checkpoint::cleanup(log.dir(), id);
        }
        plock(&self.inner.recovered_ckpts).remove(&id);
        self.state.cv.notify_all();
        self.inner.publish(&self.state, JobStatus::Cancelled);
        true
    }

    /// Live per-job metrics window (empty until the job starts running,
    /// and empty again once the job reaches a terminal phase — the
    /// service releases a finished job's metric scope to keep long-lived
    /// deployments at steady-state memory; the full per-job snapshot
    /// survives in [`JobOutcome::metrics`]).
    pub fn metrics(&self) -> MetricsSnapshot {
        self.inner.session.cluster().metrics_scoped(self.state.id)
    }

    /// Render this job's optimized plan — fusions, CSE cache points,
    /// predicted shuffle stages, and cache decisions per node.
    pub fn explain(&self) -> Result<String> {
        self.inner.session.explain_expr(&self.state.expr)
    }

    /// Run the static plan verifier on this job's plan (no execution):
    /// proved geometry, derived cost profile, rewrite- and
    /// lifecycle-soundness — the engine behind
    /// `GET /v1/jobs/:id/analysis`. Valid at any phase; the prediction is
    /// a property of the plan, not of the run.
    pub fn analysis(&self) -> Result<crate::analysis::PlanVerdict> {
        self.inner.session.analyze_expr(&self.state.expr)
    }

    /// Blocks of this job's plan that were materialized **on the driver**
    /// at submit. Always 0 for spec-described inputs — the lazy-leaf
    /// invariant `spin bench` measures and gates per run.
    pub fn submit_driver_blocks(&self) -> usize {
        self.state.expr.driver_source_blocks()
    }
}

/// Terminal jobs retained in the service's job index (the HTTP status
/// endpoint's lookup window). Beyond the cap the oldest terminal entries
/// are forgotten — outstanding [`JobHandle`]s stay fully usable; only
/// id-based lookup of long-finished jobs stops resolving.
const JOB_RETENTION_CAP: usize = 256;

/// Per-job phase-transition history cap. A job's lifecycle is a handful
/// of transitions; the cap only matters as a hard bound so a pathological
/// path (or a future retry loop) can't grow the event bus without limit —
/// the oldest events are dropped first.
const JOB_EVENT_HISTORY_CAP: usize = 32;

/// Per-tenant occupancy, reported as gauges by `/v1/metrics` and the
/// serve summary.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TenantGauge {
    pub tenant: String,
    /// Jobs queued and not yet claimed.
    pub queued: usize,
    /// Jobs currently running on a worker.
    pub running: usize,
}

/// One event-bus listener (see [`SpinService::subscribe`]).
struct Subscriber {
    /// `None` = all jobs.
    job: Option<u64>,
    tx: mpsc::Sender<JobEvent>,
    /// Identity for drop-time deregistration (see [`EventSubscription`]).
    token: u64,
}

/// A live event subscription: the receiver plus drop-time
/// deregistration. `publish` prunes a subscriber only when a send to it
/// fails, and only for events matching its filter — so a listener on an
/// already-terminal job (a dead SSE socket, an abandoned receiver)
/// would otherwise sit in the subscriber list forever. Dropping this
/// guard frees the slot deterministically. Derefs to the underlying
/// [`mpsc::Receiver`].
pub struct EventSubscription {
    rx: mpsc::Receiver<JobEvent>,
    token: u64,
    inner: Arc<ServiceInner>,
}

impl std::ops::Deref for EventSubscription {
    type Target = mpsc::Receiver<JobEvent>;

    fn deref(&self) -> &Self::Target {
        &self.rx
    }
}

impl Drop for EventSubscription {
    fn drop(&mut self) {
        plock(&self.inner.subscribers).retain(|s| s.token != self.token);
    }
}

struct ServiceInner {
    session: SpinSession,
    plans: PlanCache,
    queue: Mutex<FairShareQueue<Arc<JobState>>>,
    work_cv: Condvar,
    shutdown: AtomicBool,
    next_job: AtomicU64,
    /// Every job the service still remembers, by id — the authority for
    /// id-stable resubmits and status-by-id lookups.
    jobs: Mutex<BTreeMap<u64, Arc<JobState>>>,
    subscribers: Mutex<Vec<Subscriber>>,
    event_seq: AtomicU64,
    /// Subscription tokens (see [`EventSubscription`]).
    sub_seq: AtomicU64,
    /// Durable job log (`spin serve --http --store DIR`); `None` for
    /// purely in-process services.
    job_log: Option<Arc<JobLog>>,
    /// Jobs currently running per tenant (the in-flight cap's gauge).
    running: Mutex<BTreeMap<String, usize>>,
    /// Checkpoint records replayed from the job log, keyed by job id —
    /// attached to the job's execution when it is resubmitted, consumed
    /// at its terminal.
    recovered_ckpts: Mutex<BTreeMap<u64, Vec<CheckpointRecord>>>,
}

impl ServiceInner {
    fn submit(self: &Arc<Self>, spec: JobSpec, fixed_id: Option<u64>) -> Result<JobHandle> {
        if self.shutdown.load(Ordering::SeqCst) {
            return Err(SpinError::cluster("service is shutting down"));
        }
        for matrix in spec.matrices() {
            matrix.validate()?;
        }
        // Resolve the scheme now: an unknown name must fail at submit,
        // not minutes later on a worker thread.
        let algo = spec
            .algo
            .clone()
            .unwrap_or_else(|| self.session.default_algorithm().to_string());
        let scheme = self.session.registry().get(&algo)?;
        // Iterative knobs only make sense for iterative schemes; a spec
        // that sets them for an exact algorithm is misconfigured, and the
        // knobs would otherwise be silently ignored.
        if (spec.tolerance.is_some() || spec.max_iters.is_some()) && !scheme.iterative() {
            return Err(SpinError::config(format!(
                "`tolerance`/`max_iters` apply only to iterative algorithms, \
                 but `{algo}` is exact"
            )));
        }
        let (expr, residual_source) = self.build_plan(&spec, &algo)?;
        // Ids start at 1: scope 0 stays the ambient (non-job) scope.
        let id = match fixed_id {
            Some(id) => {
                if id == 0 {
                    return Err(SpinError::config("job ids start at 1"));
                }
                // Keep auto-allocation above every externally fixed id.
                self.next_job.fetch_max(id, Ordering::Relaxed);
                id
            }
            None => self.next_job.fetch_add(1, Ordering::Relaxed) + 1,
        };
        let state = Arc::new(JobState {
            id,
            spec,
            expr,
            residual_source,
            phase: Mutex::new(Phase::Queued),
            cv: Condvar::new(),
            history: Mutex::new(Vec::new()),
        });
        {
            // Register under the jobs lock: the index is the idempotency
            // authority, so a concurrent resubmit of the same id cannot
            // double-enqueue.
            let mut jobs = plock(&self.jobs);
            if let Some(existing) = jobs.get(&id) {
                if existing.spec == state.spec {
                    return Ok(JobHandle {
                        state: Arc::clone(existing),
                        inner: Arc::clone(self),
                    });
                }
                return Err(SpinError::config(format!(
                    "job {id} already exists with a different spec"
                )));
            }
            jobs.insert(id, Arc::clone(&state));
            if jobs.len() > JOB_RETENTION_CAP {
                let excess = jobs.len() - JOB_RETENTION_CAP;
                let evict: Vec<u64> = jobs
                    .iter()
                    .filter(|(_, j)| phase_status(&plock(&j.phase)).is_terminal())
                    .map(|(&jid, _)| jid)
                    .take(excess)
                    .collect();
                for jid in evict {
                    jobs.remove(&jid);
                }
            }
        }
        // Durability before visibility: the submitted record must be on
        // disk before the id is acknowledged or a worker can run the job.
        if let Some(log) = &self.job_log {
            if let Err(e) = log.record_submitted(id, &state.spec) {
                plock(&self.jobs).remove(&id);
                return Err(e);
            }
        }
        self.publish(&state, JobStatus::Queued);
        let pushed = {
            let mut queue = plock(&self.queue);
            let quota = self.session.cluster().config().tenant_queue_quota;
            if quota > 0 && queue.tenant_len(&state.spec.tenant) >= quota {
                Err(SpinError::cluster(format!(
                    "tenant `{}` is over its queue quota ({quota} jobs queued)",
                    state.spec.tenant
                )))
            } else {
                queue.push(&state.spec.tenant, Arc::clone(&state))
            }
        };
        if let Err(e) = pushed {
            // Queue full: withdraw the job entirely. The log pairs the
            // submitted record with a cancelled terminal so a restart
            // does not resurrect a job the client saw rejected.
            plock(&self.jobs).remove(&id);
            *plock(&state.phase) = Phase::Cancelled;
            let msg = e.to_string();
            self.log_terminal(id, JobStatus::Cancelled, Some(&msg), None);
            self.publish(&state, JobStatus::Cancelled);
            return Err(e);
        }
        self.work_cv.notify_one();
        Ok(JobHandle {
            state,
            inner: Arc::clone(self),
        })
    }

    /// Publish one phase transition: record it in the job's history and
    /// fan it out to live subscribers (dead receivers are dropped).
    /// Called with no service locks held except what `history` needs.
    fn publish(&self, job: &JobState, status: JobStatus) {
        let event = JobEvent {
            seq: self.event_seq.fetch_add(1, Ordering::Relaxed) + 1,
            job_id: job.id,
            status,
            ts_ms: now_ms(),
        };
        {
            let mut history = plock(&job.history);
            history.push(event.clone());
            if history.len() > JOB_EVENT_HISTORY_CAP {
                let excess = history.len() - JOB_EVENT_HISTORY_CAP;
                history.drain(..excess);
            }
        }
        let mut subs = plock(&self.subscribers);
        subs.retain(|s| {
            if s.job.is_some_and(|id| id != event.job_id) {
                return true;
            }
            s.tx.send(event.clone()).is_ok()
        });
    }

    /// Append a terminal record to the durable job log, if one is
    /// attached. A failing append degrades durability (a restart may
    /// re-run the job) but must not fail the job itself.
    fn log_terminal(&self, id: u64, status: JobStatus, error: Option<&str>, residual: Option<f64>) {
        if let Some(log) = &self.job_log {
            if let Err(e) = log.record_terminal(id, status, error, residual) {
                log::warn!("job log append failed for job {id}: {e}");
            }
        }
    }

    /// Pop the next runnable job and claim its phase (`Queued` →
    /// `Running`) in ONE queue-lock critical section. This closes the
    /// cancel race: there is no instant where a job is out of the queue
    /// but not yet `Running`, so `cancel()` (which removes from the queue
    /// under the same lock) either fully wins — the job never runs — or
    /// cleanly loses.
    fn claim_next(&self) -> Option<Arc<JobState>> {
        let mut queue = plock(&self.queue);
        claim_from(self, &mut queue)
    }

    /// Lower a spec onto interned plan nodes (the cross-job sharing
    /// point: equal sub-structure → same `Arc`'d node).
    fn build_plan(&self, spec: &JobSpec, algo: &str) -> Result<(MatExpr, Option<MatExpr>)> {
        let opts = spec.invert_opts();
        match &spec.kind {
            JobKind::Invert { matrix } => {
                let src = self.plans.source(matrix)?;
                Ok((self.plans.invert(&src, algo, opts)?, Some(src)))
            }
            JobKind::Solve { matrix, rhs } => {
                let a = self.plans.source(matrix)?;
                let b = self.plans.source(rhs)?;
                let inv = self.plans.invert(&a, algo, opts)?;
                Ok((self.plans.multiply(&inv, &b)?, None))
            }
            JobKind::Multiply { a, b } => {
                let ea = self.plans.source(a)?;
                let eb = self.plans.source(b)?;
                Ok((self.plans.multiply(&ea, &eb)?, None))
            }
            JobKind::PseudoInverse { matrix } => {
                let m = self.plans.source(matrix)?;
                let mt = self.plans.transpose(&m)?;
                let gram = self.plans.multiply(&mt, &m)?;
                let gram_inv = self.plans.invert(&gram, algo, opts)?;
                Ok((self.plans.multiply(&gram_inv, &mt)?, Some(m)))
            }
        }
    }

    /// Execute one claimed job (phase already `Running`) on the calling
    /// thread. A panicking execution — a generator, a user-registered
    /// algorithm, a worker task — fails *this job* and leaves the service
    /// serving: the panic is caught here, and every lock it may have
    /// poisoned on the way up is poison-tolerant (`util::plock`).
    fn run_job(&self, job: &Arc<JobState>) {
        self.publish(job, JobStatus::Running);
        let outcome = {
            // Everything this job records on the shared cluster is tagged
            // with its id, so per-job windows stay exact under
            // concurrency. The checkpoint context (when checkpointing is
            // on) rides the same thread for the same span.
            let _ckpt = self.install_checkpoints(job);
            let _scope = Metrics::enter_scope(job.id);
            panic::catch_unwind(AssertUnwindSafe(|| self.execute(job)))
        };
        // Terminal: drop the job's metric scope so a long-lived service
        // holds steady-state memory. The outcome snapshot was taken
        // inside execute(), so per-job introspection survives in
        // JobOutcome. Release BEFORE the phase flips: a waiter woken by
        // wait() must observe the retention counters already settled.
        self.session.cluster().release_metrics_scope(job.id);
        let terminal = match outcome {
            Ok(Ok(o)) => Phase::Completed(o),
            Ok(Err(e)) => Phase::Failed(e.to_string()),
            Err(payload) => Phase::Failed(format!("panicked: {}", panic_message(payload))),
        };
        // Durability before visibility: the terminal record is fsynced
        // before any waiter/poller can observe the flip, so a job a
        // client saw finish never re-executes after a crash-restart.
        let (status, error, residual) = match &terminal {
            Phase::Completed(o) => (JobStatus::Completed, None, o.residual),
            Phase::Failed(msg) => (JobStatus::Failed, Some(msg.clone()), None),
            _ => unreachable!("run_job only produces completed/failed"),
        };
        self.log_terminal(job.id, status, error.as_deref(), residual);
        // A terminal job's checkpoints can never be restored again: free
        // the disk and the replayed records.
        if let Some(log) = &self.job_log {
            if self.session.cluster().config().checkpoint_every_level > 0 {
                checkpoint::cleanup(log.dir(), job.id);
            }
        }
        plock(&self.recovered_ckpts).remove(&job.id);
        let mut phase = plock(&job.phase);
        // Don't overwrite a terminal another path already set (the drain
        // deadline hard-fails wedged jobs; if one finishes after all, the
        // hard-fail verdict the client saw stands).
        let already_terminal = phase_status(&phase).is_terminal();
        if !already_terminal {
            *phase = terminal;
        }
        drop(phase);
        job.cv.notify_all();
        if !already_terminal {
            self.publish(job, status);
        }
        // Free the tenant's in-flight slot and wake the workers: a capped
        // tenant's queued jobs become claimable the moment one finishes.
        {
            let mut running = plock(&self.running);
            if let Some(n) = running.get_mut(&job.spec.tenant) {
                *n = n.saturating_sub(1);
                if *n == 0 {
                    running.remove(&job.spec.tenant);
                }
            }
        }
        self.work_cv.notify_all();
    }

    /// Install the thread-local checkpoint context for one job run, when
    /// checkpointing is configured (`checkpoint_every_level > 0`) and a
    /// durable job log exists to journal the records (checkpoints without
    /// a journal could never be trusted at replay).
    fn install_checkpoints(&self, job: &JobState) -> Option<checkpoint::InstallGuard> {
        let every = self.session.cluster().config().checkpoint_every_level;
        if every == 0 {
            return None;
        }
        let log = self.job_log.as_ref()?;
        let records = plock(&self.recovered_ckpts)
            .get(&job.id)
            .cloned()
            .unwrap_or_default();
        Some(checkpoint::install(
            job.id,
            log.dir(),
            every,
            Some(Arc::clone(log)),
            &records,
        ))
    }

    fn execute(&self, job: &JobState) -> Result<JobOutcome> {
        let result = self.session.materialize(&job.expr)?;
        let dense = result.to_dense()?;
        let residual = match &job.residual_source {
            Some(src) => {
                let src_dense = self.session.materialize(src)?.to_dense()?;
                Some(inverse_residual(&src_dense, &dense))
            }
            None => None,
        };
        Ok(JobOutcome {
            dense,
            residual,
            metrics: self.session.cluster().metrics_scoped(job.id),
        })
    }
}

/// Pop+claim under the caller's queue lock (see
/// [`ServiceInner::claim_next`]). Tenants at their in-flight cap are
/// skipped (their jobs stay queued; other tenants keep flowing) and the
/// claimed tenant's running count is bumped before the queue lock is
/// released, so two workers can never over-admit one tenant. The
/// defensive skip of a non-`Queued` phase cannot fire under the current
/// invariants (queued jobs are always `Queued` — cancel removes them
/// before flipping the phase) but keeps the loop safe if a new terminal
/// path ever appears.
fn claim_from(
    inner: &ServiceInner,
    queue: &mut FairShareQueue<Arc<JobState>>,
) -> Option<Arc<JobState>> {
    let cap = inner.session.cluster().config().tenant_inflight_cap;
    loop {
        let job = if cap == 0 {
            queue.pop()
        } else {
            let running = plock(&inner.running);
            queue.pop_where(|tenant| running.get(tenant).copied().unwrap_or(0) < cap)
        }?;
        let mut phase = plock(&job.phase);
        if matches!(*phase, Phase::Queued) {
            *phase = Phase::Running;
            drop(phase);
            *plock(&inner.running)
                .entry(job.spec.tenant.clone())
                .or_insert(0) += 1;
            return Some(job);
        }
    }
}

fn worker_loop(inner: Arc<ServiceInner>) {
    loop {
        let job = {
            let mut queue = plock(&inner.queue);
            loop {
                if let Some(job) = claim_from(&inner, &mut queue) {
                    break Some(job);
                }
                if inner.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                queue = pwait(&inner.work_cv, queue);
            }
        };
        match job {
            Some(job) => inner.run_job(&job),
            None => return,
        }
    }
}

/// Builder for [`SpinService`].
pub struct ServiceBuilder {
    session: SessionBuilder,
    workers: usize,
    queue_capacity: usize,
    job_log: Option<Arc<JobLog>>,
}

impl Default for ServiceBuilder {
    fn default() -> Self {
        ServiceBuilder {
            session: SessionBuilder::default(),
            workers: 2,
            queue_capacity: 64,
            job_log: None,
        }
    }
}

impl ServiceBuilder {
    /// Replace the whole underlying session configuration.
    pub fn session_builder(mut self, session: SessionBuilder) -> Self {
        self.session = session;
        self
    }

    /// Local single-node cluster with `cores` task slots.
    pub fn cores(mut self, cores: usize) -> Self {
        self.session = self.session.cores(cores);
        self
    }

    /// Replace the cluster topology (including `cache_budget_bytes`).
    pub fn cluster_config(mut self, cfg: ClusterConfig) -> Self {
        self.session = self.session.cluster_config(cfg);
        self
    }

    /// Scheme used when a spec names none.
    pub fn default_algorithm(mut self, name: &str) -> Self {
        self.session = self.session.default_algorithm(name);
        self
    }

    /// Job-executor threads. `0` = no background execution: jobs queue
    /// until [`SpinService::run_pending`] drains them on the caller's
    /// thread (deterministic tests, batch drivers).
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    /// Bound on queued (not yet running) jobs across all tenants.
    pub fn queue_capacity(mut self, capacity: usize) -> Self {
        self.queue_capacity = capacity;
        self
    }

    /// Attach a durable [`JobLog`]: every accepted submit and every
    /// terminal phase is fsynced to it before becoming visible, which is
    /// what makes `spin serve --http` crash-restartable.
    pub fn job_log(mut self, log: Arc<JobLog>) -> Self {
        self.job_log = Some(log);
        self
    }

    pub fn build(self) -> Result<SpinService> {
        let session = self.session.build()?;
        let inner = Arc::new(ServiceInner {
            session,
            plans: PlanCache::new(),
            queue: Mutex::new(FairShareQueue::new(self.queue_capacity)),
            work_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            next_job: AtomicU64::new(0),
            jobs: Mutex::new(BTreeMap::new()),
            subscribers: Mutex::new(Vec::new()),
            event_seq: AtomicU64::new(0),
            sub_seq: AtomicU64::new(0),
            job_log: self.job_log,
            running: Mutex::new(BTreeMap::new()),
            recovered_ckpts: Mutex::new(BTreeMap::new()),
        });
        let workers = (0..self.workers)
            .map(|i| {
                let inner = Arc::clone(&inner);
                thread::Builder::new()
                    .name(format!("spin-service-{i}"))
                    .spawn(move || worker_loop(inner))
            })
            .collect::<std::io::Result<Vec<_>>>()?;
        Ok(SpinService { inner, workers })
    }
}

/// The job service: one shared session/cluster, a worker pool draining a
/// fair-share queue, a cross-job plan cache, and per-job introspection.
/// Dropping the service stops the workers; still-queued jobs are marked
/// cancelled (running jobs finish first — drop joins the workers).
pub struct SpinService {
    inner: Arc<ServiceInner>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl SpinService {
    pub fn builder() -> ServiceBuilder {
        ServiceBuilder::default()
    }

    /// Queue a job and return its handle in **O(1) matrix work**: the
    /// calling thread only validates the spec and builds (or re-interns)
    /// lazy plan nodes — a `MatrixSpec`'s blocks are produced
    /// per-partition on the workers at first materialization, never
    /// driver-side here. Equal specs still intern to one shared plan
    /// leaf (the cache key is unchanged). Fails fast on bad geometry,
    /// unknown algorithms, missing stores, or a saturated queue.
    pub fn submit(&self, spec: JobSpec) -> Result<JobHandle> {
        self.inner.submit(spec, None)
    }

    /// [`submit`](SpinService::submit) under a caller-chosen job id — the
    /// id-stable path HTTP resubmits and job-log replay use. Submitting
    /// an id the service already knows is **idempotent**: the same spec
    /// returns the existing job's handle (no second execution); a
    /// different spec under a taken id is an error. Auto-allocated ids
    /// always stay above every fixed id seen.
    pub fn submit_with_id(&self, id: u64, spec: JobSpec) -> Result<JobHandle> {
        self.inner.submit(spec, Some(id))
    }

    /// Look up a job the service still remembers by id. Retention is
    /// bounded: past the cap the oldest *terminal* jobs are forgotten
    /// (outstanding handles stay valid; only id lookup stops resolving).
    pub fn job(&self, id: u64) -> Option<JobHandle> {
        plock(&self.inner.jobs).get(&id).map(|state| JobHandle {
            state: Arc::clone(state),
            inner: Arc::clone(&self.inner),
        })
    }

    /// Subscribe to phase-transition events — for one job, or all jobs
    /// (`None`). Returns the history so far plus a live receiver. The
    /// subscriber is registered *before* the history snapshot is taken,
    /// so every event is in the snapshot or the live feed (possibly
    /// both — dedup on [`JobEvent::seq`]); none can fall between.
    /// Dropping the returned [`EventSubscription`] deregisters the
    /// listener even if no further event for its job ever fires.
    pub fn subscribe(&self, job: Option<u64>) -> (Vec<JobEvent>, EventSubscription) {
        let (tx, rx) = mpsc::channel();
        let token = self.inner.sub_seq.fetch_add(1, Ordering::Relaxed) + 1;
        plock(&self.inner.subscribers).push(Subscriber { job, tx, token });
        let mut history: Vec<JobEvent> = {
            let jobs = plock(&self.inner.jobs);
            match job {
                Some(id) => jobs
                    .get(&id)
                    .map(|j| plock(&j.history).clone())
                    .unwrap_or_default(),
                None => jobs
                    .values()
                    .flat_map(|j| plock(&j.history).clone())
                    .collect(),
            }
        };
        history.sort_by_key(|e| e.seq);
        let sub = EventSubscription {
            rx,
            token,
            inner: Arc::clone(&self.inner),
        };
        (history, sub)
    }

    /// Attach checkpoint records replayed from the job log to a job id
    /// that is about to be resubmitted ([`SpinService::submit_with_id`]).
    /// When the job runs, each recorded recursion level restores from the
    /// block store instead of recomputing. Records are consumed (and the
    /// on-disk checkpoints deleted) when the job reaches a terminal.
    pub fn preload_checkpoints(&self, id: u64, records: Vec<CheckpointRecord>) {
        if records.is_empty() {
            return;
        }
        plock(&self.inner.recovered_ckpts).insert(id, records);
    }

    /// Per-tenant queued/running occupancy, sorted by tenant name —
    /// `/v1/metrics` gauges and the serve summary.
    pub fn tenant_gauges(&self) -> Vec<TenantGauge> {
        let mut by_tenant: BTreeMap<String, TenantGauge> = BTreeMap::new();
        for (tenant, queued) in plock(&self.inner.queue).tenant_counts() {
            by_tenant.insert(
                tenant.clone(),
                TenantGauge {
                    tenant,
                    queued,
                    running: 0,
                },
            );
        }
        for (tenant, &running) in plock(&self.inner.running).iter() {
            by_tenant
                .entry(tenant.clone())
                .or_insert_with(|| TenantGauge {
                    tenant: tenant.clone(),
                    queued: 0,
                    running: 0,
                })
                .running = running;
        }
        by_tenant.into_values().collect()
    }

    /// Block until no remembered job is queued or running — the graceful
    /// drain behind ctrl-c on `spin serve --http`. The caller must have
    /// stopped submitting (or have workers running) or this never
    /// returns.
    pub fn wait_idle(&self) {
        loop {
            let pending: Vec<Arc<JobState>> = plock(&self.inner.jobs)
                .values()
                .filter(|j| !phase_status(&plock(&j.phase)).is_terminal())
                .cloned()
                .collect();
            if pending.is_empty() {
                return;
            }
            for job in pending {
                let mut phase = plock(&job.phase);
                while !phase_status(&phase).is_terminal() {
                    phase = pwait(&job.cv, phase);
                }
            }
        }
    }

    /// [`wait_idle`](SpinService::wait_idle) with a deadline: returns
    /// `true` if every remembered job reached a terminal within
    /// `timeout`, `false` if some are still queued/running (the caller
    /// then decides — `spin serve`'s drain deadline hard-fails them via
    /// [`fail_pending`](SpinService::fail_pending)).
    pub fn wait_idle_timeout(&self, timeout: std::time::Duration) -> bool {
        let deadline = std::time::Instant::now() + timeout;
        loop {
            let any_pending = plock(&self.inner.jobs)
                .values()
                .any(|j| !phase_status(&plock(&j.phase)).is_terminal());
            if !any_pending {
                return true;
            }
            if std::time::Instant::now() >= deadline {
                return false;
            }
            thread::sleep(std::time::Duration::from_millis(20));
        }
    }

    /// Hard-fail every job that is not yet terminal — the drain
    /// deadline's last resort. Queued jobs are removed from the queue so
    /// no worker claims them; each failed job gets a journaled terminal
    /// record (durability before visibility, like every other terminal)
    /// so a restarted server serves the verdict instead of re-running a
    /// job the operator decided to abandon. Returns how many jobs were
    /// failed. A still-running job's thread is not interrupted — its
    /// eventual result is discarded (the hard-fail terminal stands).
    pub fn fail_pending(&self, reason: &str) -> usize {
        // Empty the queue first: a drained job can no longer be claimed.
        let _abandoned = plock(&self.inner.queue).drain();
        let pending: Vec<Arc<JobState>> = plock(&self.inner.jobs)
            .values()
            .filter(|j| !phase_status(&plock(&j.phase)).is_terminal())
            .cloned()
            .collect();
        let mut failed = 0;
        for job in pending {
            // Journal first; the record wins replay even if the running
            // thread finishes later (first terminal per id wins).
            self.inner
                .log_terminal(job.id, JobStatus::Failed, Some(reason), None);
            let mut phase = plock(&job.phase);
            if phase_status(&phase).is_terminal() {
                continue;
            }
            *phase = Phase::Failed(reason.to_string());
            drop(phase);
            job.cv.notify_all();
            self.inner.publish(&job, JobStatus::Failed);
            failed += 1;
        }
        failed
    }

    /// Run queued jobs on the calling thread until the queue is empty;
    /// returns how many ran. The synchronous driver for `workers(0)`
    /// services (batch replay, deterministic tests); safe alongside
    /// background workers too.
    pub fn run_pending(&self) -> usize {
        let mut ran = 0;
        while let Some(job) = self.inner.claim_next() {
            self.inner.run_job(&job);
            ran += 1;
        }
        ran
    }

    /// The shared session every job executes on.
    pub fn session(&self) -> &SpinSession {
        &self.inner.session
    }

    /// Cross-job plan-cache counters.
    pub fn plan_cache_stats(&self) -> PlanCacheStats {
        self.inner.plans.stats()
    }

    /// Value-lifecycle counters (resident bytes, budget, evictions).
    pub fn cache_stats(&self) -> CacheStats {
        self.inner.session.cache_stats()
    }

    /// Cluster-global metrics across all jobs.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.inner.session.metrics()
    }

    /// Jobs queued and not yet picked up.
    pub fn queued_jobs(&self) -> usize {
        plock(&self.inner.queue).len()
    }

    /// Background worker threads.
    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for SpinService {
    fn drop(&mut self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        // Abandon still-queued jobs so their waiters unblock. This is
        // deliberately NOT logged as terminal: a shutdown-abandoned job
        // was never finished, so a restarted server re-enqueues it from
        // the durable log.
        let abandoned = plock(&self.inner.queue).drain();
        for job in abandoned {
            let mut phase = plock(&job.phase);
            if matches!(*phase, Phase::Queued) {
                *phase = Phase::Cancelled;
            }
            drop(phase);
            job.cv.notify_all();
            self.inner.publish(&job, JobStatus::Cancelled);
        }
        self.inner.work_cv.notify_all();
        for worker in self.workers.drain(..) {
            let _ = worker.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sync_service() -> SpinService {
        SpinService::builder().cores(2).workers(0).build().unwrap()
    }

    #[test]
    fn submit_wait_invert_matches_session() {
        let service = SpinService::builder().cores(2).workers(1).build().unwrap();
        let handle = service
            .submit(JobSpec::invert(MatrixSpec::new(32, 8).seeded(5)).label("inv"))
            .unwrap();
        let outcome = handle.wait().unwrap();
        assert_eq!(handle.status(), JobStatus::Completed);
        assert!(outcome.residual.unwrap() < 1e-9);
        assert!(outcome.metrics.method("multiply").is_some());
        assert_eq!(outcome.metrics.driver_collects(), 0);
        // Reference: the same inversion through a plain session.
        let session = SpinSession::local(2).unwrap();
        let a = session.random_seeded(32, 8, 5).unwrap();
        let want = a.inverse().unwrap().to_dense().unwrap();
        assert_eq!(outcome.dense.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn submit_validates_before_queueing() {
        let service = sync_service();
        // Bad geometry.
        let err = service
            .submit(JobSpec::invert(MatrixSpec::new(100, 10)))
            .unwrap_err();
        assert!(err.to_string().contains("power of two"), "{err}");
        // Unknown algorithm.
        let err = service
            .submit(JobSpec::invert(MatrixSpec::new(16, 4)).algorithm("qr"))
            .unwrap_err();
        assert!(err.to_string().contains("unknown algorithm"), "{err}");
        // Grid mismatch inside a binary kind.
        let err = service
            .submit(JobSpec::multiply(
                MatrixSpec::new(16, 4),
                MatrixSpec::new(16, 8),
            ))
            .unwrap_err();
        assert!(err.to_string().contains("grid mismatch"), "{err}");
        assert_eq!(service.queued_jobs(), 0, "nothing bad was queued");
    }

    #[test]
    fn queue_capacity_backpressure_and_cancel() {
        let service = SpinService::builder()
            .cores(2)
            .workers(0)
            .queue_capacity(2)
            .build()
            .unwrap();
        let spec = || JobSpec::invert(MatrixSpec::new(16, 4));
        let h1 = service.submit(spec()).unwrap();
        let h2 = service.submit(spec().tenant("other")).unwrap();
        let err = service.submit(spec()).unwrap_err();
        assert!(err.to_string().contains("queue is full"), "{err}");
        // Cancelling a queued job frees its slot immediately.
        assert!(h2.cancel());
        assert!(!h2.cancel(), "second cancel is a no-op");
        assert_eq!(h2.status(), JobStatus::Cancelled);
        assert!(h2.wait().unwrap_err().to_string().contains("cancelled"));
        assert_eq!(service.queued_jobs(), 1, "cancel must relieve backpressure");
        let h3 = service.submit(spec().tenant("third")).unwrap();
        assert_eq!(service.run_pending(), 2, "h1 and h3 run; h2 never pops");
        assert_eq!(h1.status(), JobStatus::Completed);
        assert_eq!(h3.status(), JobStatus::Completed);
        // A completed job cannot be cancelled.
        assert!(!h1.cancel());
    }

    #[test]
    fn shared_subexpression_executes_once_across_jobs() {
        let service = sync_service();
        let a = MatrixSpec::new(64, 16).seeded(0xA);
        let b = MatrixSpec::new(64, 16).seeded(0xB);
        let inv = service.submit(JobSpec::invert(a.clone())).unwrap();
        let solve = service.submit(JobSpec::solve(a, b)).unwrap();
        assert_eq!(service.run_pending(), 2);
        let inv_out = inv.wait().unwrap();
        let solve_out = solve.wait().unwrap();
        assert!(inv_out.residual.unwrap() < 1e-9);
        assert!(solve_out.residual.is_none());
        // The invert[spin](A) node is interned once, so across BOTH jobs
        // the recursion's leaves ran exactly once: grid 4 → 4 leaf calls.
        let total = service.metrics();
        assert_eq!(total.method("leafNode").unwrap().calls, 4);
        // Plan cache saw the share: the solve's invert lookup was a hit.
        let stats = service.plan_cache_stats();
        assert!(stats.hits >= 2, "source + invert re-lookups hit: {stats:?}");
        // Per-job attribution: the solve job paid the inversion (it ran
        // second only in submission order — the scheduler interleaves
        // tenants, but here both are `default`), while the other job got
        // the memoized value. Exactly one job carries the leaf stages.
        let inv_leaves = inv_out
            .metrics
            .method("leafNode")
            .map(|s| s.calls)
            .unwrap_or(0);
        let solve_leaves = solve_out
            .metrics
            .method("leafNode")
            .map(|s| s.calls)
            .unwrap_or(0);
        assert_eq!(inv_leaves + solve_leaves, 4);
    }

    #[test]
    fn per_job_metrics_are_scoped_and_released_on_completion() {
        let service = sync_service();
        let h1 = service
            .submit(JobSpec::multiply(
                MatrixSpec::new(16, 4).seeded(1),
                MatrixSpec::new(16, 4).seeded(2),
            ))
            .unwrap();
        let h2 = service
            .submit(JobSpec::multiply(
                MatrixSpec::new(16, 4).seeded(3),
                MatrixSpec::new(16, 4).seeded(4),
            ))
            .unwrap();
        service.run_pending();
        let m1 = h1.wait().unwrap().metrics;
        let m2 = h2.wait().unwrap().metrics;
        // Each distinct multiply pays its own single shuffle round (2
        // exchange stages) — and ONLY its own.
        assert_eq!(m1.method("multiply").unwrap().shuffle_stages, 2);
        assert_eq!(m2.method("multiply").unwrap().shuffle_stages, 2);
        assert_eq!(service.metrics().total_shuffle_stages(), 4);
        // Terminal jobs' scopes are RELEASED: the live handle view reads
        // empty (the outcome snapshot above is the durable record), and
        // the retention counters account for both scopes.
        assert_eq!(h1.metrics().stages().len(), 0);
        let total = service.metrics();
        assert_eq!(total.released_scopes(), 2);
        assert!(total.released_stage_records() > 0);
        assert_eq!(
            total.retained_stage_records(),
            total.stages().len(),
            "retained counter matches what the global snapshot holds"
        );
        assert_eq!(
            total.retained_stage_records(),
            0,
            "all work ran under job scopes, so nothing is retained"
        );
    }

    #[test]
    fn pseudo_inverse_job_and_explain() {
        let service = sync_service();
        let handle = service
            .submit(JobSpec::pseudo_inverse(MatrixSpec::new(32, 8).seeded(9).spd()))
            .unwrap();
        // explain works while the job is still queued.
        let text = handle.explain().unwrap();
        assert!(text.contains("invert[spin]"), "{text}");
        assert!(text.contains("transpose"), "{text}");
        service.run_pending();
        let out = handle.wait().unwrap();
        assert!(out.residual.unwrap() < 1e-8);
    }

    #[test]
    fn failed_job_reports_error() {
        use crate::algos::InversionAlgorithm;
        use crate::blockmatrix::BlockMatrix;
        use crate::cluster::Cluster;
        use crate::config::JobConfig;
        use crate::runtime::BlockKernels;

        struct Exploding;
        impl InversionAlgorithm for Exploding {
            fn name(&self) -> &str {
                "exploding"
            }
            fn invert(
                &self,
                _cluster: &Cluster,
                _kernels: &dyn BlockKernels,
                _a: &BlockMatrix,
                _job: &JobConfig,
            ) -> Result<BlockMatrix> {
                Err(SpinError::numerical("boom"))
            }
        }
        let service = SpinService::builder()
            .session_builder(
                SpinSession::builder()
                    .cores(2)
                    .register_algorithm(Arc::new(Exploding))
                    .unwrap(),
            )
            .workers(0)
            .build()
            .unwrap();
        let h = service
            .submit(JobSpec::invert(MatrixSpec::new(16, 4)).algorithm("exploding"))
            .unwrap();
        service.run_pending();
        assert_eq!(h.status(), JobStatus::Failed);
        let err = h.wait().unwrap_err().to_string();
        assert!(err.contains("failed") && err.contains("boom"), "{err}");
        // A failed job cannot be cancelled after the fact.
        assert!(!h.cancel());
    }

    #[test]
    fn fair_share_run_order_across_tenants() {
        let service = sync_service();
        let spec = |seed: u64, tenant: &str| {
            JobSpec::multiply(
                MatrixSpec::new(16, 4).seeded(seed),
                MatrixSpec::new(16, 4).seeded(seed + 100),
            )
            .tenant(tenant)
        };
        let a1 = service.submit(spec(1, "alice")).unwrap();
        let a2 = service.submit(spec(2, "alice")).unwrap();
        let b1 = service.submit(spec(3, "bob")).unwrap();
        // Synchronous drain pops in fair-share order: alice, bob, alice.
        // Job ids are submission-ordered, so check scope stage ordering
        // via the global stage stream: run one job at a time.
        assert_eq!(service.queued_jobs(), 3);
        let first = {
            let job = service.inner.claim_next().unwrap();
            let id = job.id;
            service.inner.run_job(&job);
            id
        };
        let second = {
            let job = service.inner.claim_next().unwrap();
            let id = job.id;
            service.inner.run_job(&job);
            id
        };
        assert_eq!(first, a1.id());
        assert_eq!(second, b1.id(), "bob's turn before alice's backlog");
        service.run_pending();
        assert_eq!(a2.status(), JobStatus::Completed);
        for h in [a1, a2, b1] {
            h.wait().unwrap();
        }
    }

    /// Acceptance (lazy sources): `submit()` performs ZERO block
    /// generation on the driver — no stage of any kind is recorded until
    /// a worker materializes the job — and the generation stage then
    /// lands in the job's own metric scope.
    #[test]
    fn submit_generates_nothing_on_the_driver() {
        let service = sync_service();
        let handle = service
            .submit(JobSpec::invert(MatrixSpec::new(64, 16).seeded(42)))
            .unwrap();
        assert_eq!(service.queued_jobs(), 1);
        let before = service.metrics();
        assert!(
            before.stages().is_empty(),
            "submit must not run any stage (driver-side generation is gone)"
        );
        assert_eq!(before.retained_stage_records(), 0);
        assert!(before.method("generate").is_none());
        assert_eq!(
            handle.submit_driver_blocks(),
            0,
            "the plan must hold no driver-materialized source blocks"
        );
        // The plan is fully known pre-materialization: explain works on a
        // queued job and shows the lazy leaf.
        let text = handle.explain().unwrap();
        assert!(text.contains("lazy_source"), "{text}");
        service.run_pending();
        let out = handle.wait().unwrap();
        assert!(out.residual.unwrap() < 1e-9);
        // Generation ran as a distributed stage in THIS job's scope: one
        // call, one task per block of the 4x4 grid, fully narrow.
        let gen = out.metrics.method("generate").expect("generate stage");
        assert_eq!(gen.calls, 1);
        assert_eq!(gen.tasks, 16);
        assert_eq!(gen.shuffle_stages, 0);
        assert_eq!(out.metrics.driver_collects(), 0);
        // Global (lifetime) aggregates saw it exactly once too.
        assert_eq!(service.metrics().method("generate").unwrap().calls, 1);
    }

    /// Acceptance (lazy/eager equivalence + sharing): concurrent jobs
    /// over the same spec share ONE interned lazy leaf — generation runs
    /// once, attributed to exactly one job — and the result is
    /// bit-identical to the eager session path.
    #[test]
    fn lazy_leaf_shared_across_jobs_generates_once() {
        let service = sync_service();
        let spec = MatrixSpec::new(64, 16).seeded(0x5EED);
        let h1 = service.submit(JobSpec::invert(spec.clone())).unwrap();
        let h2 = service
            .submit(JobSpec::multiply(spec.clone(), spec.clone()).tenant("other"))
            .unwrap();
        assert_eq!(service.run_pending(), 2);
        let o1 = h1.wait().unwrap();
        let o2 = h2.wait().unwrap();
        // One shared leaf ⇒ the generate stage ran exactly once across
        // both jobs, and exactly one job's scope carries it.
        assert_eq!(service.metrics().method("generate").unwrap().calls, 1);
        let gen_calls = |m: &MetricsSnapshot| m.method("generate").map(|s| s.calls).unwrap_or(0);
        assert_eq!(gen_calls(&o1.metrics) + gen_calls(&o2.metrics), 1);
        // Bit-identity with the eager session path.
        let session = SpinSession::local(2).unwrap();
        let a = session.random_seeded(64, 16, 0x5EED).unwrap();
        let want_inv = a.inverse().unwrap().to_dense().unwrap();
        let want_sq = a.multiply(&a).unwrap().to_dense().unwrap();
        assert_eq!(o1.dense.max_abs_diff(&want_inv), 0.0);
        assert_eq!(o2.dense.max_abs_diff(&want_sq), 0.0);
    }

    /// Satellite (bugfix): a job whose execution PANICS — here a
    /// user-registered algorithm — fails that job with the panic message
    /// while the service (workers, queue, shared plan nodes whose locks
    /// the panic poisoned) keeps serving.
    #[test]
    fn panicking_job_fails_while_service_keeps_serving() {
        use crate::algos::InversionAlgorithm;
        use crate::blockmatrix::BlockMatrix;
        use crate::cluster::Cluster;
        use crate::config::JobConfig;
        use crate::runtime::BlockKernels;

        struct Panicking;
        impl InversionAlgorithm for Panicking {
            fn name(&self) -> &str {
                "panicking"
            }
            fn invert(
                &self,
                _cluster: &Cluster,
                _kernels: &dyn BlockKernels,
                _a: &BlockMatrix,
                _job: &JobConfig,
            ) -> Result<BlockMatrix> {
                panic!("generator blew up");
            }
        }
        let service = SpinService::builder()
            .session_builder(
                SpinSession::builder()
                    .cores(2)
                    .register_algorithm(Arc::new(Panicking))
                    .unwrap(),
            )
            .workers(1)
            .build()
            .unwrap();
        let spec = || JobSpec::invert(MatrixSpec::new(16, 4)).algorithm("panicking");
        let bad = service.submit(spec()).unwrap();
        let err = bad.wait().unwrap_err().to_string();
        assert_eq!(bad.status(), JobStatus::Failed);
        assert!(
            err.contains("panicked") && err.contains("generator blew up"),
            "{err}"
        );
        // The SAME interned plan node (whose memo lock the panic
        // poisoned) fails cleanly again rather than wedging the worker.
        let again = service.submit(spec()).unwrap();
        assert!(again.wait().is_err());
        // And an honest job on the surviving worker completes.
        let good = service
            .submit(JobSpec::invert(MatrixSpec::new(16, 4)))
            .unwrap();
        let out = good.wait().unwrap();
        assert_eq!(good.status(), JobStatus::Completed);
        assert!(out.residual.unwrap() < 1e-9);
        // Failed jobs release their metric scopes like completed ones.
        assert_eq!(service.metrics().released_scopes(), 3);
    }

    /// Satellite (bugfix): the cancel/claim race is closed — workers
    /// claim the phase under the queue lock, so `cancel()` either fully
    /// wins (job removed, never runs) or cleanly loses (job runs to a
    /// terminal state). The barrier maximizes the historic race window;
    /// the invariant must hold for every interleaving.
    #[test]
    fn cancel_and_claim_race_is_atomic() {
        // Deterministic directions first. Cancel before any claim: wins,
        // and the claimer then finds nothing.
        let service = sync_service();
        let spec = || {
            JobSpec::multiply(
                MatrixSpec::new(16, 4).seeded(1),
                MatrixSpec::new(16, 4).seeded(2),
            )
        };
        let h = service.submit(spec()).unwrap();
        assert!(h.cancel());
        assert!(service.inner.claim_next().is_none());
        assert_eq!(h.status(), JobStatus::Cancelled);
        // Claim before cancel: cancel must lose and the job completes.
        let h = service.submit(spec()).unwrap();
        let job = service.inner.claim_next().unwrap();
        assert!(!h.cancel(), "claimed job is no longer cancellable");
        service.inner.run_job(&job);
        assert_eq!(h.status(), JobStatus::Completed);

        // Racing direction: whatever the interleaving, exactly one side
        // wins and the loser observes it consistently.
        for round in 0..16 {
            let h = service.submit(spec()).unwrap();
            let barrier = std::sync::Barrier::new(2);
            let (ran, cancelled) = std::thread::scope(|scope| {
                let runner = scope.spawn(|| {
                    barrier.wait();
                    service.run_pending()
                });
                let canceller = scope.spawn(|| {
                    barrier.wait();
                    h.cancel()
                });
                (runner.join().unwrap(), canceller.join().unwrap())
            });
            if cancelled {
                assert_eq!(ran, 0, "round {round}: cancelled job must never run");
                assert_eq!(h.status(), JobStatus::Cancelled);
                assert!(h.wait().is_err());
            } else {
                assert_eq!(ran, 1, "round {round}: uncancelled job runs exactly once");
                assert_eq!(h.status(), JobStatus::Completed);
                h.wait().unwrap();
            }
            assert_eq!(service.queued_jobs(), 0);
        }
    }

    #[test]
    fn events_record_phase_transitions_in_order() {
        let service = sync_service();
        let (history, rx) = service.subscribe(None);
        assert!(history.is_empty());
        let h = service
            .submit(JobSpec::invert(MatrixSpec::new(16, 4).seeded(3)))
            .unwrap();
        service.run_pending();
        h.wait().unwrap();
        let statuses: Vec<JobStatus> = h.history().iter().map(|e| e.status).collect();
        assert_eq!(
            statuses,
            vec![JobStatus::Queued, JobStatus::Running, JobStatus::Completed]
        );
        // Seqs strictly increase and timestamps are populated.
        let seqs: Vec<u64> = h.history().iter().map(|e| e.seq).collect();
        assert!(seqs.windows(2).all(|w| w[0] < w[1]), "{seqs:?}");
        assert!(h.history().iter().all(|e| e.ts_ms > 0));
        // The live subscriber saw the same three events.
        let live: Vec<JobStatus> = rx.try_iter().map(|e| e.status).collect();
        assert_eq!(live, statuses);
        // Cancelled jobs publish a cancelled terminal event.
        let h2 = service
            .submit(JobSpec::invert(MatrixSpec::new(16, 4).seeded(4)))
            .unwrap();
        assert!(h2.cancel());
        let statuses: Vec<JobStatus> = h2.history().iter().map(|e| e.status).collect();
        assert_eq!(statuses, vec![JobStatus::Queued, JobStatus::Cancelled]);
        // A job-filtered subscriber gets h2's history only.
        let (history, _rx) = service.subscribe(Some(h2.id()));
        assert_eq!(history.len(), 2);
        assert!(history.iter().all(|e| e.job_id == h2.id()));
    }

    /// Satellite (SSE hygiene): a listener on an already-terminal job
    /// never receives another event, so publish-side pruning cannot
    /// reach it — the subscription guard's drop must free the slot. And
    /// per-job event history is bounded, so a pathological job cannot
    /// grow server memory without limit.
    #[test]
    fn dropped_subscription_frees_its_slot_and_history_is_bounded() {
        let service = sync_service();
        let h = service
            .submit(JobSpec::invert(MatrixSpec::new(16, 4).seeded(5)))
            .unwrap();
        service.run_pending();
        h.wait().unwrap();
        let (history, sub) = service.subscribe(Some(h.id()));
        assert_eq!(history.last().unwrap().status, JobStatus::Completed);
        assert_eq!(plock(&service.inner.subscribers).len(), 1);
        drop(sub);
        assert_eq!(
            plock(&service.inner.subscribers).len(),
            0,
            "dead subscriber slot freed without waiting for a failed send"
        );
        // Flood the job with events: history stays capped, newest kept.
        for _ in 0..(JOB_EVENT_HISTORY_CAP * 2) {
            service.inner.publish(&h.state, JobStatus::Running);
        }
        let history = h.history();
        assert_eq!(history.len(), JOB_EVENT_HISTORY_CAP);
        let seqs: Vec<u64> = history.iter().map(|e| e.seq).collect();
        assert!(seqs.windows(2).all(|w| w[0] < w[1]), "newest retained");
    }

    #[test]
    fn submit_with_id_is_idempotent_by_id() {
        let service = sync_service();
        let spec = JobSpec::invert(MatrixSpec::new(16, 4).seeded(7));
        let h = service.submit_with_id(42, spec.clone()).unwrap();
        assert_eq!(h.id(), 42);
        // Same id + same spec: the existing job, not a second execution.
        let again = service.submit_with_id(42, spec.clone()).unwrap();
        assert_eq!(again.id(), 42);
        assert_eq!(service.queued_jobs(), 1, "no duplicate enqueue");
        // Same id + different spec: refused.
        let err = service
            .submit_with_id(42, JobSpec::invert(MatrixSpec::new(32, 8)))
            .unwrap_err();
        assert!(err.to_string().contains("different spec"), "{err}");
        // Id 0 is reserved for the ambient scope.
        assert!(service.submit_with_id(0, spec.clone()).is_err());
        // Auto-allocation continues above the fixed id.
        let auto = service.submit(spec.clone().tenant("other")).unwrap();
        assert!(auto.id() > 42, "auto id {} must exceed fixed 42", auto.id());
        // Lookup by id resolves both.
        assert_eq!(service.job(42).unwrap().id(), 42);
        assert!(service.job(999).is_none());
        service.run_pending();
        assert_eq!(h.status(), JobStatus::Completed);
        assert_eq!(again.status(), JobStatus::Completed, "same underlying job");
        // Resubmit after completion still returns the finished job.
        let after = service.submit_with_id(42, spec).unwrap();
        assert_eq!(after.status(), JobStatus::Completed);
        assert!(after.outcome().is_some());
        assert_eq!(
            after.terminal().unwrap().status,
            JobStatus::Completed,
            "terminal summary available"
        );
    }

    #[test]
    fn job_log_records_lifecycle_and_replay_resumes_pending() {
        use crate::store::joblog::JobLog;
        let dir = std::env::temp_dir().join(format!("spin_svc_log_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let (log, replay) = JobLog::open(&dir).unwrap();
        assert_eq!(replay.jobs.len(), 0);
        let spec_a = JobSpec::invert(MatrixSpec::new(16, 4).seeded(1)).label("a");
        let spec_b = JobSpec::invert(MatrixSpec::new(16, 4).seeded(2)).label("b");
        {
            let service = SpinService::builder()
                .cores(2)
                .workers(0)
                .job_log(Arc::new(log))
                .build()
                .unwrap();
            let a = service.submit(spec_a.clone()).unwrap();
            let _b = service.submit(spec_b.clone()).unwrap();
            // Only job a runs before the "crash" (service drop).
            let job = service.inner.claim_next().unwrap();
            service.inner.run_job(&job);
            a.wait().unwrap();
        }
        // Restart: replay finds a terminal for a, b still pending.
        let (log, replay) = JobLog::open(&dir).unwrap();
        assert_eq!(log.generation(), 2);
        let pending: Vec<&crate::store::ReplayedJob> = replay.pending().collect();
        assert_eq!(pending.len(), 1);
        assert_eq!(pending[0].spec, spec_b);
        let done = replay.jobs.iter().find(|j| j.terminal.is_some()).unwrap();
        assert_eq!(done.spec, spec_a);
        let t = done.terminal.as_ref().unwrap();
        assert_eq!(t.status, JobStatus::Completed);
        assert!(t.residual.unwrap() < 1e-9);
        // Re-enqueue the pending job under its original id.
        let service = SpinService::builder()
            .cores(2)
            .workers(0)
            .job_log(Arc::new(log))
            .build()
            .unwrap();
        let h = service
            .submit_with_id(pending[0].id, pending[0].spec.clone())
            .unwrap();
        assert_eq!(h.id(), 2);
        service.run_pending();
        h.wait().unwrap();
        drop(service);
        // Third generation: everything terminal, nothing pending.
        let (_, replay) = JobLog::open(&dir).unwrap();
        assert_eq!(replay.pending().count(), 0);
        assert_eq!(replay.jobs.len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Satellite (tenant protection): queue quota rejects a flooding
    /// tenant's submits with a `quota` error (HTTP maps it to 429), the
    /// in-flight cap keeps a tenant's claims bounded while other tenants
    /// keep flowing, and the gauges report both sides.
    #[test]
    fn tenant_quota_and_inflight_cap_protect_other_tenants() {
        let mut cfg = ClusterConfig::local(2);
        cfg.tenant_queue_quota = 2;
        cfg.tenant_inflight_cap = 1;
        let service = SpinService::builder()
            .cluster_config(cfg)
            .workers(0)
            .queue_capacity(16)
            .build()
            .unwrap();
        let spec = |seed: u64| {
            JobSpec::multiply(
                MatrixSpec::new(16, 4).seeded(seed),
                MatrixSpec::new(16, 4).seeded(seed + 50),
            )
        };
        let a1 = service.submit(spec(1).tenant("alice")).unwrap();
        let a2 = service.submit(spec(2).tenant("alice")).unwrap();
        let err = service.submit(spec(3).tenant("alice")).unwrap_err();
        assert!(err.to_string().contains("quota"), "{err}");
        // The rejected job left no residue: not queued, not remembered.
        assert_eq!(service.queued_jobs(), 2);
        // Another tenant is untouched by alice's quota.
        let b1 = service.submit(spec(4).tenant("bob")).unwrap();
        let gauges = service.tenant_gauges();
        let alice = gauges.iter().find(|g| g.tenant == "alice").unwrap();
        assert_eq!((alice.queued, alice.running), (2, 0));
        // Claim 1 takes alice's head; alice is then AT her in-flight cap,
        // so claim 2 must skip her backlog and serve bob.
        let j1 = service.inner.claim_next().unwrap();
        assert_eq!(j1.id, a1.id());
        let j2 = service.inner.claim_next().unwrap();
        assert_eq!(j2.id, b1.id(), "capped tenant must not block the rotation");
        assert!(
            service.inner.claim_next().is_none(),
            "alice's second job is unclaimable while she is at cap"
        );
        let gauges = service.tenant_gauges();
        let alice = gauges.iter().find(|g| g.tenant == "alice").unwrap();
        assert_eq!((alice.queued, alice.running), (1, 1));
        // Finishing a job frees the slot; the backlog then drains.
        service.inner.run_job(&j1);
        service.inner.run_job(&j2);
        let j3 = service.inner.claim_next().unwrap();
        assert_eq!(j3.id, a2.id());
        service.inner.run_job(&j3);
        for h in [a1, a2, b1] {
            assert_eq!(h.status(), JobStatus::Completed);
        }
        assert!(service.tenant_gauges().is_empty(), "all gauges settled");
    }

    /// Satellite (drain deadline): `fail_pending` hard-fails everything
    /// not yet terminal with a journaled record, and `wait_idle_timeout`
    /// reports whether the drain beat the deadline.
    #[test]
    fn drain_deadline_hard_fails_pending_jobs_durably() {
        use crate::store::joblog::JobLog;
        let dir = std::env::temp_dir().join(format!("spin_svc_drain_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let (log, _) = JobLog::open(&dir).unwrap();
        let service = SpinService::builder()
            .cores(2)
            .workers(0)
            .job_log(Arc::new(log))
            .build()
            .unwrap();
        let h1 = service
            .submit(JobSpec::invert(MatrixSpec::new(16, 4).seeded(1)))
            .unwrap();
        let h2 = service
            .submit(JobSpec::invert(MatrixSpec::new(16, 4).seeded(2)).tenant("other"))
            .unwrap();
        // No workers: the queue is wedged by construction.
        assert!(!service.wait_idle_timeout(std::time::Duration::from_millis(60)));
        assert_eq!(service.fail_pending("drain timeout"), 2);
        assert!(service.wait_idle_timeout(std::time::Duration::from_millis(10)));
        for h in [&h1, &h2] {
            assert_eq!(h.status(), JobStatus::Failed);
            let t = h.terminal().unwrap();
            assert!(t.error.as_deref().unwrap().contains("drain timeout"));
        }
        assert_eq!(service.queued_jobs(), 0, "queue emptied, nothing claimable");
        drop(service);
        // The terminals are durable: a restart resumes nothing.
        let (_, replay) = JobLog::open(&dir).unwrap();
        assert_eq!(replay.pending().count(), 0);
        assert!(replay
            .jobs
            .iter()
            .all(|j| j.terminal.as_ref().unwrap().status == JobStatus::Failed));
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Tentpole (checkpoint/resume): a job whose process dies after its
    /// recursion levels were checkpointed — journal has `submitted` +
    /// `checkpoint` records but no terminal — is re-enqueued on restart
    /// and RESTORES the checkpointed levels instead of recomputing: zero
    /// leaf stages in the resumed job's scope, bit-identical result, and
    /// the checkpoint dir is reclaimed at the terminal.
    #[test]
    fn checkpointed_job_resumes_from_journaled_levels_after_crash() {
        use crate::runtime::NativeBackend;
        use crate::store::joblog::JobLog;
        let dir = std::env::temp_dir().join(format!("spin_svc_ckpt_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut cfg = ClusterConfig::local(2);
        cfg.checkpoint_every_level = 1;
        let spec = JobSpec::invert(MatrixSpec::new(32, 8).seeded(0xCE));
        // Clean reference result.
        let session = SpinSession::local(2).unwrap();
        let a = session.random_seeded(32, 8, 0xCE).unwrap();
        let want = a.inverse().unwrap().to_dense().unwrap();

        // Generation 1: the job is durably submitted, and the worker gets
        // as far as checkpointing every level — then the process "dies"
        // before any terminal is logged. We drive the algorithm by hand
        // under the same checkpoint context a worker would install.
        {
            let (log, _) = JobLog::open(&dir).unwrap();
            let log = Arc::new(log);
            let service = SpinService::builder()
                .cluster_config(cfg.clone())
                .workers(0)
                .job_log(Arc::clone(&log))
                .build()
                .unwrap();
            let h = service.submit(spec.clone()).unwrap();
            assert_eq!(h.id(), 1);
            let _ctx = checkpoint::install(1, log.dir(), 1, Some(Arc::clone(&log)), &[]);
            let cluster = crate::cluster::Cluster::new(ClusterConfig::local(2));
            let mut job = crate::config::JobConfig::new(32, 8);
            job.seed = 0xCE;
            let a = crate::blockmatrix::BlockMatrix::random(&job).unwrap();
            let _ = crate::algos::spin::spin_inverse_impl(&cluster, &NativeBackend, &a, &job)
                .unwrap();
            // Service drop abandons the queued job WITHOUT a terminal
            // record — exactly a crash's disk state.
        }

        // Generation 2: replay finds the pending job with its journal of
        // checkpoints; the server re-enqueues it with them preloaded.
        let (log, replay) = JobLog::open(&dir).unwrap();
        let pending: Vec<&crate::store::ReplayedJob> = replay.pending().collect();
        assert_eq!(pending.len(), 1);
        let keys: Vec<&str> = pending[0].checkpoints.iter().map(|c| c.key.as_str()).collect();
        assert!(keys.contains(&"r-m"), "root level journaled: {keys:?}");
        assert!(keys.contains(&"r.0-m") && keys.contains(&"r.1-m"), "{keys:?}");
        let service = SpinService::builder()
            .cluster_config(cfg)
            .workers(0)
            .job_log(Arc::new(log))
            .build()
            .unwrap();
        service.preload_checkpoints(pending[0].id, pending[0].checkpoints.clone());
        let h = service
            .submit_with_id(pending[0].id, pending[0].spec.clone())
            .unwrap();
        service.run_pending();
        let out = h.wait().unwrap();
        // The restored root level skipped the ENTIRE recursion: no leaf
        // inversion stage ran in this job's scope.
        assert!(
            out.metrics.method("leafNode").is_none(),
            "resumed job must not recompute checkpointed levels"
        );
        assert!(out.metrics.resilience().checkpoints_restored >= 1);
        assert_eq!(out.metrics.resilience().checkpoints_written, 0);
        // Bit-identical to the clean, uninterrupted run.
        assert_eq!(out.dense.max_abs_diff(&want), 0.0);
        assert!(out.residual.unwrap() < 1e-8);
        // Terminal reclaims the checkpoint storage.
        assert!(!dir.join("checkpoints").join("job_1").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Satellite (store round-trip): ingest → `from_store` → invert on
    /// the service; blocks are loaded by the workers, the result matches
    /// the generated twin bit-for-bit, and the residual passes.
    #[test]
    fn store_backed_job_loads_on_workers_and_inverts() {
        let dir = std::env::temp_dir().join(format!("spin_svc_store_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut job = crate::config::JobConfig::new(32, 8);
        job.seed = 0xAB;
        let store = crate::store::LocalDirStore::create(&dir, 4, 8).unwrap();
        crate::store::ingest_generated(&store, &job).unwrap();

        let service = sync_service();
        let spec = MatrixSpec::from_store(&dir).unwrap();
        let handle = service.submit(JobSpec::invert(spec)).unwrap();
        service.run_pending();
        let out = handle.wait().unwrap();
        assert!(out.residual.unwrap() < 1e-8);
        let load = out.metrics.method("loadBlock").expect("store load stage");
        assert_eq!(load.calls, 1);
        assert_eq!(load.tasks, 16);
        // The store held the same bits the generator produces, so the
        // inverse equals the generated twin's inverse exactly.
        let session = SpinSession::local(2).unwrap();
        let a = session.random_seeded(32, 8, 0xAB).unwrap();
        let want = a.inverse().unwrap().to_dense().unwrap();
        assert_eq!(out.dense.max_abs_diff(&want), 0.0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
