//! Bounded fair-share queue: jobs are bucketed per tenant and drained
//! round-robin across tenants with queued work, so one chatty tenant
//! cannot starve the others — a heavy submitter only competes with
//! itself. Total occupancy is capped; `push` fails when the service is
//! saturated (backpressure instead of unbounded memory).

use std::collections::{BTreeMap, VecDeque};

use crate::error::{Result, SpinError};

pub(crate) struct FairShareQueue<T> {
    capacity: usize,
    queues: BTreeMap<String, VecDeque<T>>,
    /// Rotation of tenants with non-empty queues, each exactly once.
    rr: VecDeque<String>,
    len: usize,
}

impl<T> FairShareQueue<T> {
    pub fn new(capacity: usize) -> Self {
        FairShareQueue {
            capacity: capacity.max(1),
            queues: BTreeMap::new(),
            rr: VecDeque::new(),
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    /// Items queued under one tenant (the per-tenant quota gauge).
    pub fn tenant_len(&self, tenant: &str) -> usize {
        self.queues.get(tenant).map_or(0, |q| q.len())
    }

    /// Queued-count per tenant, for metrics gauges.
    pub fn tenant_counts(&self) -> Vec<(String, usize)> {
        self.queues
            .iter()
            .map(|(tenant, q)| (tenant.clone(), q.len()))
            .collect()
    }

    /// Enqueue under `tenant`; errors when the service is saturated.
    pub fn push(&mut self, tenant: &str, item: T) -> Result<()> {
        if self.len >= self.capacity {
            return Err(SpinError::cluster(format!(
                "service queue is full ({} jobs queued, capacity {})",
                self.len, self.capacity
            )));
        }
        let queue = self.queues.entry(tenant.to_string()).or_default();
        if queue.is_empty() {
            self.rr.push_back(tenant.to_string());
        }
        queue.push_back(item);
        self.len += 1;
        Ok(())
    }

    /// Next job, round-robin across tenants: take the head of the front
    /// tenant's queue, then rotate that tenant to the back (if it still
    /// has work).
    pub fn pop(&mut self) -> Option<T> {
        self.pop_where(|_| true)
    }

    /// [`pop`](Self::pop), skipping tenants `admit` rejects (the
    /// in-flight cap): a blocked tenant rotates to the back and an
    /// admitted one is served, so a capped tenant never blocks the rest
    /// of the rotation. Returns `None` when no admitted tenant has work.
    pub fn pop_where(&mut self, admit: impl Fn(&str) -> bool) -> Option<T> {
        for _ in 0..self.rr.len() {
            let tenant = self.rr.pop_front()?;
            if !admit(&tenant) {
                self.rr.push_back(tenant);
                continue;
            }
            let Some(queue) = self.queues.get_mut(&tenant) else {
                continue;
            };
            let Some(item) = queue.pop_front() else {
                self.queues.remove(&tenant);
                continue;
            };
            if queue.is_empty() {
                self.queues.remove(&tenant);
            } else {
                self.rr.push_back(tenant);
            }
            self.len -= 1;
            return Some(item);
        }
        None
    }

    /// Remove one queued item of `tenant` matching `pred` (job
    /// cancellation): the slot frees immediately, so cancelling relieves
    /// backpressure instead of waiting for a worker to pop-and-discard.
    pub fn remove_where(&mut self, tenant: &str, pred: impl Fn(&T) -> bool) -> Option<T> {
        let queue = self.queues.get_mut(tenant)?;
        let pos = queue.iter().position(pred)?;
        let item = queue.remove(pos)?;
        if queue.is_empty() {
            self.queues.remove(tenant);
            self.rr.retain(|name| name != tenant);
        }
        self.len -= 1;
        Some(item)
    }

    /// Remove everything (service shutdown): returns the abandoned items
    /// so the caller can mark them cancelled.
    pub fn drain(&mut self) -> Vec<T> {
        let mut out = Vec::with_capacity(self.len);
        while let Some(item) = self.pop() {
            out.push(item);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_across_tenants() {
        let mut q = FairShareQueue::new(16);
        // alice floods, bob and carol each submit one.
        q.push("alice", "a1").unwrap();
        q.push("alice", "a2").unwrap();
        q.push("alice", "a3").unwrap();
        q.push("bob", "b1").unwrap();
        q.push("carol", "c1").unwrap();
        let order: Vec<&str> = std::iter::from_fn(|| q.pop()).collect();
        // alice first (submitted first), then each other tenant gets a
        // turn before alice's backlog continues.
        assert_eq!(order, vec!["a1", "b1", "c1", "a2", "a3"]);
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn capacity_backpressure() {
        let mut q = FairShareQueue::new(2);
        q.push("t", 1).unwrap();
        q.push("t", 2).unwrap();
        let err = q.push("t", 3).unwrap_err();
        assert!(err.to_string().contains("capacity 2"), "{err}");
        assert_eq!(q.pop(), Some(1));
        q.push("t", 3).unwrap(); // space again after a pop
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn drain_empties_in_fair_order() {
        let mut q = FairShareQueue::new(8);
        q.push("x", 1).unwrap();
        q.push("y", 2).unwrap();
        q.push("x", 3).unwrap();
        assert_eq!(q.drain(), vec![1, 2, 3]);
        assert_eq!(q.len(), 0);
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn remove_where_frees_slot_and_keeps_rotation_sound() {
        let mut q = FairShareQueue::new(2);
        q.push("x", 1).unwrap();
        q.push("y", 2).unwrap();
        assert!(q.push("x", 3).is_err(), "full");
        // Removing x's only item drops x from the rotation entirely.
        assert_eq!(q.remove_where("x", |&v| v == 1), Some(1));
        assert_eq!(q.len(), 1);
        assert_eq!(q.remove_where("x", |&v| v == 1), None);
        q.push("z", 4).unwrap(); // slot freed
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(4));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn pop_where_skips_capped_tenants_without_starving_others() {
        let mut q = FairShareQueue::new(8);
        q.push("alice", "a1").unwrap();
        q.push("alice", "a2").unwrap();
        q.push("bob", "b1").unwrap();
        assert_eq!(q.tenant_len("alice"), 2);
        assert_eq!(q.tenant_len("nobody"), 0);
        // alice is at her in-flight cap: bob is served instead.
        assert_eq!(q.pop_where(|t| t != "alice"), Some("b1"));
        // Nobody admitted → None, queue intact.
        assert_eq!(q.pop_where(|_| false), None);
        assert_eq!(q.len(), 2);
        let counts = q.tenant_counts();
        assert_eq!(counts, vec![("alice".to_string(), 2)]);
        // Cap lifted: alice drains in order.
        assert_eq!(q.pop(), Some("a1"));
        assert_eq!(q.pop(), Some("a2"));
    }

    #[test]
    fn tenant_rotation_reenters_after_empty() {
        let mut q = FairShareQueue::new(8);
        q.push("x", 1).unwrap();
        assert_eq!(q.pop(), Some(1));
        // x left the rotation when its queue emptied; re-pushing re-enters.
        q.push("x", 2).unwrap();
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }
}
