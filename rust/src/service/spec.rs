//! [`JobSpec`]: the serializable description of one service workload.
//!
//! A spec names *what* to compute — matrices are described by their
//! generator parameters (`n`, `block_size`, `seed`, family), not passed
//! by value — so specs can travel: submitted programmatically, written to
//! a script file and replayed by `spin serve --script`, or logged for
//! reproduction. Two specs describing the same matrix intern to the same
//! plan source (see [`crate::service::PlanCache`]), which is what lets
//! concurrent jobs share materialized subexpressions.

use std::path::PathBuf;

use crate::config::{GeneratorKind, JobConfig};
use crate::error::{Result, SpinError};
use crate::plan::SourceSpec;
use crate::ser::bin;
use crate::ser::json::Json;

/// Largest seed a spec accepts: JSON numbers are f64, so only integers
/// up to 2⁵³ round-trip exactly — a lossy seed would silently describe a
/// *different* matrix after replay, breaking the sharing key's
/// bit-identity contract.
pub const MAX_SEED: u64 = 1 << 53;

/// A distributed matrix described by parameters — a generator family
/// (`n`, `block_size`, `seed`, family) or a block-store directory. Equal
/// specs denote bit-identical matrices (generation is seed-deterministic;
/// a store is one fixed on-disk matrix), so equality doubles as the
/// cross-job sharing key.
///
/// Specs are **lazy**: submitting one queues an
/// [`crate::plan::SourceSpec`] leaf whose blocks are produced
/// per-partition on the workers at first materialization — `submit()`
/// performs zero block generation or block I/O on the driver.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MatrixSpec {
    /// Matrix order (power of two).
    pub n: usize,
    /// Block edge (power of two dividing `n` into a power-of-two grid).
    pub block_size: usize,
    /// Generator seed (≤ [`MAX_SEED`] so scripts replay exactly).
    /// Ignored for store-backed specs.
    pub seed: u64,
    /// Test-matrix family. Ignored for store-backed specs.
    pub generator: GeneratorKind,
    /// When set, blocks come from this block-store directory instead of
    /// a generator (see [`MatrixSpec::from_store`]).
    pub store: Option<PathBuf>,
}

impl MatrixSpec {
    /// Diagonally-dominant matrix with the crate's default seed.
    pub fn new(n: usize, block_size: usize) -> Self {
        let j = JobConfig::new(n, block_size);
        MatrixSpec {
            n,
            block_size,
            seed: j.seed,
            generator: j.generator,
            store: None,
        }
    }

    /// Describe a matrix stored in a block-store directory. Reads only
    /// `meta.json` (grid shape, via [`SourceSpec::from_dir`]), so the
    /// handle is O(1) in the matrix size; block files are read on the
    /// workers at materialization.
    pub fn from_store(dir: impl Into<PathBuf>) -> Result<Self> {
        let SourceSpec::Store {
            dir,
            nblocks,
            block_size,
            ..
        } = SourceSpec::from_dir(dir)?
        else {
            unreachable!("from_dir always builds a store spec");
        };
        Ok(MatrixSpec {
            n: nblocks * block_size,
            block_size,
            seed: 0,
            generator: GeneratorKind::DiagDominant,
            store: Some(dir),
        })
    }

    pub fn seeded(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn spd(mut self) -> Self {
        self.generator = GeneratorKind::Spd;
        self
    }

    /// The geometry/seed checks a spec must pass before it is queued.
    /// Store-backed specs also verify the directory's `meta.json` still
    /// matches the recorded grid — a cheap driver-side read that fails a
    /// bad script at submit rather than minutes later on a worker.
    pub fn validate(&self) -> Result<()> {
        if self.store.is_none() && self.seed > MAX_SEED {
            return Err(SpinError::config(format!(
                "matrix seed {} exceeds 2^53 and would not survive a JSON \
                 round-trip (scripts must replay the exact matrix)",
                self.seed
            )));
        }
        if let Some(dir) = &self.store {
            let meta = bin::read_block_store_meta(dir)?;
            if meta.block_size != self.block_size || meta.nblocks * meta.block_size != self.n {
                return Err(SpinError::config(format!(
                    "store {} holds a {}x{} grid of {} blocks, but the spec says n={} bs={}",
                    dir.display(),
                    meta.nblocks,
                    meta.nblocks,
                    meta.block_size,
                    self.n,
                    self.block_size
                )));
            }
        }
        self.to_job().validate()
    }

    /// The lazy plan-leaf descriptor this spec lowers to. Store-backed
    /// specs re-read `meta.json` here so the leaf records the *current*
    /// store generation id (materialization re-checks it; see
    /// [`SourceSpec::Store`]).
    pub(crate) fn to_source_spec(&self) -> Result<SourceSpec> {
        match &self.store {
            Some(dir) => SourceSpec::from_dir(dir.clone()),
            None => Ok(SourceSpec::Generated {
                n: self.n,
                block_size: self.block_size,
                seed: self.seed,
                generator: self.generator,
            }),
        }
    }

    /// Full job parameters for generating this matrix.
    pub(crate) fn to_job(&self) -> JobConfig {
        let mut job = JobConfig::new(self.n, self.block_size);
        job.seed = self.seed;
        job.generator = self.generator;
        job
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("n", Json::num(self.n as f64)),
            ("block_size", Json::num(self.block_size as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("generator", Json::str(self.generator.name())),
        ];
        if let Some(dir) = &self.store {
            pairs.push(("store", Json::str(dir.to_string_lossy().to_string())));
        }
        Json::object(pairs)
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        v.check_known_keys("matrix spec", &["n", "block_size", "seed", "generator", "store"])?;
        let n = v
            .req("n")?
            .as_usize()
            .ok_or_else(|| SpinError::config("matrix `n` must be a positive integer"))?;
        let block_size = v
            .req("block_size")?
            .as_usize()
            .ok_or_else(|| SpinError::config("matrix `block_size` must be a positive integer"))?;
        let mut spec = MatrixSpec::new(n, block_size);
        if let Some(j) = v.get("seed") {
            let raw = j
                .as_i64()
                .ok_or_else(|| SpinError::config("matrix `seed` must be an integer"))?;
            spec.seed = u64::try_from(raw)
                .ok()
                .filter(|&s| s <= MAX_SEED)
                .ok_or_else(|| {
                    SpinError::config(format!(
                        "matrix `seed` must be an integer in [0, 2^53], got {raw}"
                    ))
                })?;
        }
        if let Some(j) = v.get("generator") {
            spec.generator = GeneratorKind::parse(
                j.as_str()
                    .ok_or_else(|| SpinError::config("matrix `generator` must be a string"))?,
            )?;
        }
        if let Some(j) = v.get("store") {
            spec.store = Some(PathBuf::from(
                j.as_str()
                    .ok_or_else(|| SpinError::config("matrix `store` must be a string path"))?,
            ));
        }
        Ok(spec)
    }
}

/// The workload shape.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum JobKind {
    /// A⁻¹.
    Invert { matrix: MatrixSpec },
    /// X = A⁻¹·B for a distributed right-hand side.
    Solve { matrix: MatrixSpec, rhs: MatrixSpec },
    /// C = A·B.
    Multiply { a: MatrixSpec, b: MatrixSpec },
    /// M⁺ = (MᵀM)⁻¹·Mᵀ.
    PseudoInverse { matrix: MatrixSpec },
}

impl JobKind {
    /// Stable kind tag used by JSON and reports.
    pub fn name(&self) -> &'static str {
        match self {
            JobKind::Invert { .. } => "invert",
            JobKind::Solve { .. } => "solve",
            JobKind::Multiply { .. } => "multiply",
            JobKind::PseudoInverse { .. } => "pseudo_inverse",
        }
    }
}

/// One submittable service job: a workload plus scheduling metadata.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JobSpec {
    /// Fair-share scheduling bucket; the scheduler round-robins across
    /// tenants with queued work.
    pub tenant: String,
    /// Free-form display label for reports ("" = unnamed).
    pub label: String,
    /// Inversion scheme for kinds that invert (`None` = the service
    /// session's default algorithm). Ignored by `Multiply`.
    pub algo: Option<String>,
    /// Convergence threshold for iterative schemes. Submitting this for a
    /// non-iterative algorithm is a config error.
    pub tolerance: Option<f64>,
    /// Iteration budget (SLA bound) for iterative schemes. Submitting
    /// this for a non-iterative algorithm is a config error.
    pub max_iters: Option<usize>,
    pub kind: JobKind,
}

impl JobSpec {
    fn with_kind(kind: JobKind) -> Self {
        JobSpec {
            tenant: "default".to_string(),
            label: String::new(),
            algo: None,
            tolerance: None,
            max_iters: None,
            kind,
        }
    }

    pub fn invert(matrix: MatrixSpec) -> Self {
        JobSpec::with_kind(JobKind::Invert { matrix })
    }

    pub fn solve(matrix: MatrixSpec, rhs: MatrixSpec) -> Self {
        JobSpec::with_kind(JobKind::Solve { matrix, rhs })
    }

    pub fn multiply(a: MatrixSpec, b: MatrixSpec) -> Self {
        JobSpec::with_kind(JobKind::Multiply { a, b })
    }

    pub fn pseudo_inverse(matrix: MatrixSpec) -> Self {
        JobSpec::with_kind(JobKind::PseudoInverse { matrix })
    }

    pub fn tenant(mut self, tenant: &str) -> Self {
        self.tenant = tenant.to_string();
        self
    }

    pub fn label(mut self, label: &str) -> Self {
        self.label = label.to_string();
        self
    }

    pub fn algorithm(mut self, algo: &str) -> Self {
        self.algo = Some(algo.to_string());
        self
    }

    /// Convergence threshold for iterative schemes (e.g. `newton`).
    pub fn tolerance(mut self, tolerance: f64) -> Self {
        self.tolerance = Some(tolerance);
        self
    }

    /// Iteration budget (SLA bound) for iterative schemes.
    pub fn max_iters(mut self, max_iters: usize) -> Self {
        self.max_iters = Some(max_iters);
        self
    }

    /// The plan-node knobs this spec's iterative fields lower to.
    pub(crate) fn invert_opts(&self) -> crate::plan::InvertOpts {
        crate::plan::InvertOpts {
            tolerance: self.tolerance,
            max_iters: self.max_iters,
        }
    }

    /// Every matrix this job reads.
    pub fn matrices(&self) -> Vec<&MatrixSpec> {
        match &self.kind {
            JobKind::Invert { matrix } | JobKind::PseudoInverse { matrix } => vec![matrix],
            JobKind::Solve { matrix, rhs } => vec![matrix, rhs],
            JobKind::Multiply { a, b } => vec![a, b],
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("kind", Json::str(self.kind.name())),
            ("tenant", Json::str(self.tenant.clone())),
            ("label", Json::str(self.label.clone())),
        ];
        if let Some(algo) = &self.algo {
            pairs.push(("algo", Json::str(algo.clone())));
        }
        if let Some(tol) = self.tolerance {
            pairs.push(("tolerance", Json::num(tol)));
        }
        if let Some(iters) = self.max_iters {
            pairs.push(("max_iters", Json::num(iters as f64)));
        }
        match &self.kind {
            JobKind::Invert { matrix } | JobKind::PseudoInverse { matrix } => {
                pairs.push(("matrix", matrix.to_json()));
            }
            JobKind::Solve { matrix, rhs } => {
                pairs.push(("matrix", matrix.to_json()));
                pairs.push(("rhs", rhs.to_json()));
            }
            JobKind::Multiply { a, b } => {
                pairs.push(("a", a.to_json()));
                pairs.push(("b", b.to_json()));
            }
        }
        Json::object(pairs)
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let kind = v
            .req("kind")?
            .as_str()
            .ok_or_else(|| SpinError::config("job `kind` must be a string"))?;
        // Strict per-kind key set: a typo like `matirx` or a field from
        // the wrong kind fails the submit instead of running defaults.
        let known: &[&str] = match kind {
            "solve" => &[
                "kind",
                "tenant",
                "label",
                "algo",
                "tolerance",
                "max_iters",
                "matrix",
                "rhs",
            ],
            "multiply" => &["kind", "tenant", "label", "algo", "a", "b"],
            _ => &[
                "kind",
                "tenant",
                "label",
                "algo",
                "tolerance",
                "max_iters",
                "matrix",
            ],
        };
        v.check_known_keys(&format!("job spec ({kind})"), known)?;
        let matrix = |key: &str| -> Result<MatrixSpec> { MatrixSpec::from_json(v.req(key)?) };
        let kind = match kind {
            "invert" => JobKind::Invert {
                matrix: matrix("matrix")?,
            },
            "solve" => JobKind::Solve {
                matrix: matrix("matrix")?,
                rhs: matrix("rhs")?,
            },
            "multiply" => JobKind::Multiply {
                a: matrix("a")?,
                b: matrix("b")?,
            },
            "pseudo_inverse" => JobKind::PseudoInverse {
                matrix: matrix("matrix")?,
            },
            other => {
                return Err(SpinError::config(format!(
                    "unknown job kind `{other}` (expected invert|solve|multiply|pseudo_inverse)"
                )));
            }
        };
        let mut spec = JobSpec::with_kind(kind);
        if let Some(j) = v.get("tenant") {
            spec.tenant = j
                .as_str()
                .ok_or_else(|| SpinError::config("job `tenant` must be a string"))?
                .to_string();
        }
        if let Some(j) = v.get("label") {
            spec.label = j
                .as_str()
                .ok_or_else(|| SpinError::config("job `label` must be a string"))?
                .to_string();
        }
        if let Some(j) = v.get("algo") {
            spec.algo = Some(
                j.as_str()
                    .ok_or_else(|| SpinError::config("job `algo` must be a string"))?
                    .to_string(),
            );
        }
        if let Some(j) = v.get("tolerance") {
            let tol = j
                .as_f64()
                .filter(|t| t.is_finite() && *t > 0.0)
                .ok_or_else(|| {
                    SpinError::config("job `tolerance` must be a positive finite number")
                })?;
            spec.tolerance = Some(tol);
        }
        if let Some(j) = v.get("max_iters") {
            let iters = j.as_usize().filter(|&i| i >= 1).ok_or_else(|| {
                SpinError::config("job `max_iters` must be a positive integer")
            })?;
            spec.max_iters = Some(iters);
        }
        Ok(spec)
    }

    /// Parse a `spin serve --script` document: `{"jobs": [spec, …]}`.
    pub fn parse_script(doc: &Json) -> Result<Vec<JobSpec>> {
        doc.check_known_keys("script", &["jobs"])?;
        let jobs = doc
            .req("jobs")?
            .as_array()
            .ok_or_else(|| SpinError::config("script `jobs` must be an array"))?;
        if jobs.is_empty() {
            return Err(SpinError::config("script contains no jobs"));
        }
        jobs.iter().map(JobSpec::from_json).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_spec_round_trips() {
        let spec = MatrixSpec::new(128, 16).seeded(7).spd();
        let back = MatrixSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back, spec);
        spec.validate().unwrap();
        assert!(MatrixSpec::new(100, 10).validate().is_err());
    }

    #[test]
    fn seeds_that_cannot_round_trip_are_rejected() {
        // Above 2^53 the f64 JSON encoding is lossy: validate() refuses.
        let lossy = MatrixSpec::new(16, 4).seeded(MAX_SEED + 1);
        assert!(lossy.validate().is_err());
        MatrixSpec::new(16, 4).seeded(MAX_SEED).validate().unwrap();
        // Negative or oversized seeds in a script are parse errors.
        let mut doc = MatrixSpec::new(16, 4).to_json();
        if let Json::Object(m) = &mut doc {
            m.insert("seed".to_string(), Json::num(-1.0));
        }
        assert!(MatrixSpec::from_json(&doc).is_err());
        if let Json::Object(m) = &mut doc {
            m.insert("seed".to_string(), Json::num(9.1e15)); // > 2^53
        }
        assert!(MatrixSpec::from_json(&doc).is_err());
    }

    #[test]
    fn store_specs_round_trip_and_validate_meta() {
        // A real store on disk: from_store reads only meta.json.
        let dir = std::env::temp_dir().join(format!("spin_spec_store_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = crate::store::LocalDirStore::create(&dir, 4, 8).unwrap();
        crate::store::ingest_generated(&store, &JobConfig::new(32, 8)).unwrap();
        let spec = MatrixSpec::from_store(&dir).unwrap();
        assert_eq!((spec.n, spec.block_size), (32, 8));
        spec.validate().unwrap();
        let back = MatrixSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back, spec);
        // A replayed spec whose recorded grid disagrees with the store
        // fails validation at submit time.
        let mut lying = spec.clone();
        lying.block_size = 4;
        lying.n = 16;
        assert!(lying.validate().is_err());
        // Missing store directory fails both construction and validation.
        assert!(MatrixSpec::from_store("/definitely/missing").is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn job_specs_round_trip() {
        let a = MatrixSpec::new(64, 16).seeded(1);
        let b = MatrixSpec::new(64, 16).seeded(2);
        let specs = vec![
            JobSpec::invert(a.clone()).tenant("alice").algorithm("lu"),
            JobSpec::solve(a.clone(), b.clone()).label("gls"),
            JobSpec::multiply(a.clone(), b.clone()),
            JobSpec::pseudo_inverse(a.clone()).tenant("bob"),
            JobSpec::invert(a.clone())
                .algorithm("newton")
                .tolerance(1e-8)
                .max_iters(20),
        ];
        for spec in &specs {
            let back = JobSpec::from_json(&spec.to_json()).unwrap();
            assert_eq!(&back, spec);
        }
        assert_eq!(specs[0].kind.name(), "invert");
        assert_eq!(specs[1].matrices().len(), 2);
    }

    #[test]
    fn script_parsing_and_errors() {
        let doc = Json::object(vec![(
            "jobs",
            Json::Array(vec![JobSpec::invert(MatrixSpec::new(16, 4)).to_json()]),
        )]);
        let jobs = JobSpec::parse_script(&doc).unwrap();
        assert_eq!(jobs.len(), 1);
        assert_eq!(jobs[0].tenant, "default");
        // No jobs key / empty list / bad kind all fail.
        assert!(JobSpec::parse_script(&Json::object(vec![])).is_err());
        assert!(
            JobSpec::parse_script(&Json::object(vec![("jobs", Json::Array(vec![]))])).is_err()
        );
        let bad = Json::object(vec![("kind", Json::str("cholesky"))]);
        assert!(JobSpec::from_json(&bad).is_err());
    }

    #[test]
    fn unknown_fields_are_rejected_naming_the_key() {
        // Matrix-level typo: `blocksize` instead of `block_size`.
        let mut m = MatrixSpec::new(16, 4).to_json();
        if let Json::Object(map) = &mut m {
            map.insert("blocksize".to_string(), Json::num(4.0));
        }
        let err = MatrixSpec::from_json(&m).unwrap_err().to_string();
        assert!(err.contains("`blocksize`"), "{err}");
        // Job-level typo: `matirx` on an invert spec.
        let mut j = JobSpec::invert(MatrixSpec::new(16, 4)).to_json();
        if let Json::Object(map) = &mut j {
            map.insert("matirx".to_string(), Json::Null);
        }
        let err = JobSpec::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("`matirx`"), "{err}");
        // A field from the wrong kind: `rhs` on an invert spec.
        let mut j = JobSpec::invert(MatrixSpec::new(16, 4)).to_json();
        if let Json::Object(map) = &mut j {
            map.insert("rhs".to_string(), MatrixSpec::new(16, 4).to_json());
        }
        assert!(JobSpec::from_json(&j).is_err());
        // ...but `rhs` is fine on solve, where it belongs.
        let ok = JobSpec::solve(MatrixSpec::new(16, 4), MatrixSpec::new(16, 4));
        JobSpec::from_json(&ok.to_json()).unwrap();
        // Script-level typo.
        let doc = Json::object(vec![("job", Json::Array(vec![]))]);
        let err = JobSpec::parse_script(&doc).unwrap_err().to_string();
        assert!(err.contains("`job`"), "{err}");
    }

    #[test]
    fn iterative_knobs_validate_at_parse() {
        // Zero / negative / non-numeric tolerance and max_iters fail.
        let mut j = JobSpec::invert(MatrixSpec::new(16, 4)).to_json();
        if let Json::Object(map) = &mut j {
            map.insert("tolerance".to_string(), Json::num(0.0));
        }
        assert!(JobSpec::from_json(&j).is_err());
        if let Json::Object(map) = &mut j {
            map.insert("tolerance".to_string(), Json::num(1e-8));
            map.insert("max_iters".to_string(), Json::num(0.0));
        }
        assert!(JobSpec::from_json(&j).is_err());
        if let Json::Object(map) = &mut j {
            map.insert("max_iters".to_string(), Json::num(12.0));
        }
        let spec = JobSpec::from_json(&j).unwrap();
        assert_eq!(spec.tolerance, Some(1e-8));
        assert_eq!(spec.max_iters, Some(12));
        // `multiply` never inverts, so the keys are rejected outright.
        let mut m = JobSpec::multiply(MatrixSpec::new(16, 4), MatrixSpec::new(16, 4)).to_json();
        if let Json::Object(map) = &mut m {
            map.insert("tolerance".to_string(), Json::num(1e-8));
        }
        assert!(JobSpec::from_json(&m).is_err());
    }
}
