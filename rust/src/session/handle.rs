//! [`DistMatrix`]: a session-bound handle over a lazy [`MatExpr`] plan.
//!
//! Operator methods (`multiply`, `subtract`, `inverse`, …) are **plan
//! constructors**: they extend the expression DAG and return instantly.
//! Distributed work happens only at materialization points — [`collect`],
//! [`to_dense`], [`block_matrix`], [`inverse_residual`], `solve_dense` —
//! where the session optimizes the plan (fusion, transpose pushdown,
//! scalar folding, CSE) and lowers it onto the partitioner-aware
//! `BlockMatrix` ops. Results are memoized per plan node, so a handle
//! materializes once no matter how many times it is read, and handles
//! sharing subexpressions share their execution.
//!
//! [`collect`]: DistMatrix::collect
//! [`to_dense`]: DistMatrix::to_dense
//! [`block_matrix`]: DistMatrix::block_matrix
//! [`inverse_residual`]: DistMatrix::inverse_residual

use crate::blockmatrix::BlockMatrix;
use crate::error::{Result, SpinError};
use crate::linalg::{self, Matrix};
use crate::plan::MatExpr;
use crate::session::SpinSession;

/// A distributed square matrix bound to a [`SpinSession`] — a lazy plan
/// handle, not a materialized value.
///
/// Binary operations require both operands to share a block grid (the same
/// `nblocks` × `block_size` geometry); mismatches error at plan
/// *construction*. Handles borrow the session immutably, so any number of
/// them can be alive at once.
#[derive(Clone)]
pub struct DistMatrix<'s> {
    session: &'s SpinSession,
    expr: MatExpr,
}

impl<'s> DistMatrix<'s> {
    pub(crate) fn new(session: &'s SpinSession, expr: MatExpr) -> Self {
        DistMatrix { session, expr }
    }

    // ---------- geometry / access ----------

    /// Full matrix order `n`.
    pub fn n(&self) -> usize {
        self.expr.n()
    }

    /// Grid edge (the paper's split count `b`).
    pub fn nblocks(&self) -> usize {
        self.expr.nblocks()
    }

    pub fn block_size(&self) -> usize {
        self.expr.block_size()
    }

    /// The owning session.
    pub fn session(&self) -> &'s SpinSession {
        self.session
    }

    /// The underlying lazy expression.
    pub fn expr(&self) -> &MatExpr {
        &self.expr
    }

    /// Force evaluation (optimize + lower + execute). Idempotent: the
    /// result is memoized, so repeated calls (and every other
    /// materialization point) reuse it — until the session's LRU evictor
    /// (or [`unpersist`](Self::unpersist)) releases the value, after
    /// which the next read recomputes it bit-identically.
    pub fn collect(&self) -> Result<()> {
        self.session.materialize(&self.expr).map(|_| ())
    }

    /// Materialize this handle's value and **pin** it: the session's LRU
    /// byte-budget evictor (`ClusterConfig::cache_budget_bytes`) must not
    /// drop it. The Spark `persist()` of the lifecycle contract.
    pub fn persist(&self) -> Result<&Self> {
        self.session.materialize(&self.expr)?;
        self.session.pin_expr(&self.expr)?;
        Ok(self)
    }

    /// Unpin and immediately release this handle's materialized value
    /// (blocks payloads free as soon as no other plan shares them).
    /// Returns whether a value was actually resident. The handle stays
    /// usable: the next materialization recomputes.
    pub fn unpersist(&self) -> Result<bool> {
        self.session.unpin_expr(&self.expr)
    }

    /// Materialize into the underlying distributed matrix.
    pub fn block_matrix(&self) -> Result<BlockMatrix> {
        self.session.materialize(&self.expr)
    }

    /// Materialize and unwrap into the underlying distributed matrix.
    pub fn into_block_matrix(self) -> Result<BlockMatrix> {
        self.session.materialize(&self.expr)
    }

    /// Materialize and assemble into one dense matrix on the driver.
    pub fn to_dense(&self) -> Result<Matrix> {
        self.session.materialize(&self.expr)?.to_dense()
    }

    /// Render this handle's *optimized* plan — which fusions fired, where
    /// the CSE caches sit, and the predicted shuffle stages per node.
    pub fn explain(&self) -> Result<String> {
        self.session.explain_expr(&self.expr)
    }

    // ---------- algebra (plan constructors) ----------

    fn derived(&self, expr: MatExpr) -> DistMatrix<'s> {
        DistMatrix::new(self.session, expr)
    }

    /// A⁻¹ with the session's default algorithm (lazy).
    pub fn inverse(&self) -> Result<DistMatrix<'s>> {
        self.session.invert(self)
    }

    /// A⁻¹ through a named registry entry (`"spin"`, `"lu"`, …). The name
    /// is validated now; the inversion runs at materialization.
    pub fn inverse_with(&self, algorithm: &str) -> Result<DistMatrix<'s>> {
        self.session.invert_with(algorithm, self)
    }

    /// C = A·B (lazy distributed block matmul).
    pub fn multiply(&self, other: &DistMatrix<'_>) -> Result<DistMatrix<'s>> {
        Ok(self.derived(self.expr.multiply(other.expr())?))
    }

    /// C = A·B − D as an explicitly fused plan node. Composing
    /// [`multiply`](Self::multiply) + [`subtract`](Self::subtract) now
    /// produces the same fused stage through the optimizer — this method
    /// remains for symmetry and for `plan_optimizer = false` runs.
    pub fn multiply_sub(
        &self,
        other: &DistMatrix<'_>,
        d: &DistMatrix<'_>,
    ) -> Result<DistMatrix<'s>> {
        Ok(self.derived(self.expr.multiply_sub(other.expr(), d.expr())?))
    }

    /// C = A − B (lazy).
    pub fn subtract(&self, other: &DistMatrix<'_>) -> Result<DistMatrix<'s>> {
        Ok(self.derived(self.expr.subtract(other.expr())?))
    }

    /// C = s·A (lazy).
    pub fn scalar_mul(&self, s: f64) -> Result<DistMatrix<'s>> {
        Ok(self.derived(self.expr.scale(s)))
    }

    /// Aᵀ (lazy).
    pub fn transpose(&self) -> DistMatrix<'s> {
        self.derived(self.expr.transpose())
    }

    // ---------- solver workloads ----------

    /// Solve A·X = B for a distributed right-hand side: X = A⁻¹·B with the
    /// session's default inversion algorithm (lazy).
    pub fn solve(&self, rhs: &DistMatrix<'_>) -> Result<DistMatrix<'s>> {
        self.solve_with(self.session.default_algorithm(), rhs)
    }

    /// [`solve`](Self::solve) through a named registry entry.
    pub fn solve_with(&self, algorithm: &str, rhs: &DistMatrix<'_>) -> Result<DistMatrix<'s>> {
        self.expr.check_same_grid(rhs.expr(), "solve")?;
        self.inverse_with(algorithm)?.multiply(rhs)
    }

    /// Solve A·X = B for a driver-side dense right-hand side (`n × k`,
    /// any `k` — the GLS / kriging shape). The inversion runs distributed;
    /// the final thin product runs on the driver.
    pub fn solve_dense(&self, rhs: &Matrix) -> Result<Matrix> {
        if rhs.rows() != self.n() {
            return Err(SpinError::shape(format!(
                "solve_dense: rhs has {} rows, matrix is {}x{}",
                rhs.rows(),
                self.n(),
                self.n()
            )));
        }
        let inv = self.inverse()?.to_dense()?;
        Ok(linalg::matmul(&inv, rhs))
    }

    /// Moore–Penrose pseudo-inverse M⁺ = (MᵀM)⁻¹·Mᵀ for full-column-rank
    /// input, with the session's default inversion algorithm.
    ///
    /// The whole normal-equations pipeline is one lazy plan: `Mᵀ` is a
    /// shared subexpression (the Gram product and the final thin product
    /// both consume it), which the optimizer's CSE pass marks as a cache
    /// point — it executes once.
    pub fn pseudo_inverse(&self) -> Result<DistMatrix<'s>> {
        self.pseudo_inverse_with(self.session.default_algorithm())
    }

    /// [`pseudo_inverse`](Self::pseudo_inverse) through a named registry
    /// entry.
    pub fn pseudo_inverse_with(&self, algorithm: &str) -> Result<DistMatrix<'s>> {
        let mt = self.transpose();
        let gram = mt.multiply(self)?; //        MᵀM
        let gram_inv = gram.inverse_with(algorithm)?; // (MᵀM)⁻¹
        gram_inv.multiply(&mt) //               (MᵀM)⁻¹·Mᵀ
    }

    // ---------- checks ----------

    /// Relative inversion residual ‖A·X − I‖∞ / (‖A‖∞‖X‖∞·n) of a candidate
    /// inverse `x` against this matrix. Materializes both operands.
    pub fn inverse_residual(&self, x: &DistMatrix<'_>) -> Result<f64> {
        Ok(linalg::inverse_residual(&self.to_dense()?, &x.to_dense()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{lu_inverse, matmul};
    use crate::session::SpinSession;
    use crate::util::Rng;

    fn session() -> SpinSession {
        SpinSession::local(4).unwrap()
    }

    #[test]
    fn algebra_matches_dense() {
        let s = session();
        let a = s.random_seeded(16, 4, 1).unwrap();
        let b = s.random_seeded(16, 4, 2).unwrap();
        let (da, db) = (a.to_dense().unwrap(), b.to_dense().unwrap());
        assert!(
            a.multiply(&b)
                .unwrap()
                .to_dense()
                .unwrap()
                .max_abs_diff(&matmul(&da, &db))
                < 1e-11
        );
        assert!(
            a.subtract(&b)
                .unwrap()
                .to_dense()
                .unwrap()
                .max_abs_diff(&da.sub(&db).unwrap())
                < 1e-14
        );
        assert!(
            a.scalar_mul(-2.0)
                .unwrap()
                .to_dense()
                .unwrap()
                .max_abs_diff(&da.scale(-2.0))
                < 1e-14
        );
        assert!(
            a.transpose()
                .to_dense()
                .unwrap()
                .max_abs_diff(&da.transpose())
                < 1e-15
        );
    }

    #[test]
    fn handles_are_lazy_until_materialized() {
        let s = session();
        let a = s.random_seeded(16, 4, 20).unwrap();
        let b = s.random_seeded(16, 4, 21).unwrap();
        s.reset_clock();
        let prod = a.multiply(&b).unwrap();
        assert_eq!(
            s.metrics().stages().len(),
            0,
            "building a plan must not execute stages"
        );
        prod.collect().unwrap();
        let after_collect = s.metrics().stages().len();
        assert!(after_collect > 0, "collect materializes");
        // Re-reading is free: the plan value is memoized.
        let _ = prod.to_dense().unwrap();
        let _ = prod.block_matrix().unwrap();
        assert_eq!(s.metrics().stages().len(), after_collect);
    }

    #[test]
    fn composed_multiply_subtract_fuses_like_multiply_sub() {
        let s = session();
        let a = s.random_seeded(16, 4, 9).unwrap();
        let b = s.random_seeded(16, 4, 10).unwrap();
        let d = s.random_seeded(16, 4, 11).unwrap();
        let fused = a.multiply_sub(&b, &d).unwrap().to_dense().unwrap();
        let composed = a
            .multiply(&b)
            .unwrap()
            .subtract(&d)
            .unwrap()
            .to_dense()
            .unwrap();
        assert_eq!(
            fused.max_abs_diff(&composed),
            0.0,
            "optimizer fusion is bit-identical to the explicit fused node"
        );
        // Both lowered through multiply_sub: no standalone subtract stage.
        assert!(s.metrics().method("subtract").is_none());
    }

    #[test]
    fn explain_shows_fusion_and_predictions() {
        let s = session();
        let a = s.random_seeded(16, 4, 12).unwrap();
        let b = s.random_seeded(16, 4, 13).unwrap();
        let d = s.random_seeded(16, 4, 14).unwrap();
        let plan = a.multiply(&b).unwrap().subtract(&d).unwrap();
        let text = plan.explain().unwrap();
        assert!(text.contains("multiply_sub"), "{text}");
        assert!(text.contains("exchange stage"), "{text}");
    }

    #[test]
    fn solve_matches_serial_reference() {
        let s = session();
        let a = s.random_seeded(32, 8, 3).unwrap();
        let b = s.random_seeded(32, 8, 4).unwrap();
        let x = a.solve(&b).unwrap();
        // Reference: X = A⁻¹·B through the serial LU inverse.
        let want = matmul(
            &lu_inverse(&a.to_dense().unwrap()).unwrap(),
            &b.to_dense().unwrap(),
        );
        let diff = x.to_dense().unwrap().max_abs_diff(&want);
        assert!(diff < 1e-8, "solve diff {diff}");
        // Residual check: ‖A·X − B‖ small relative to ‖B‖.
        let ax = a.multiply(&x).unwrap().to_dense().unwrap();
        let resid = ax.max_abs_diff(&b.to_dense().unwrap()) / b.to_dense().unwrap().max_abs();
        assert!(resid < 1e-9, "solve residual {resid}");
    }

    #[test]
    fn solve_dense_rectangular_rhs() {
        let s = session();
        let a = s.random_seeded(16, 4, 5).unwrap();
        let mut rng = Rng::new(6);
        let rhs = Matrix::random_uniform(16, 3, -1.0, 1.0, &mut rng);
        let x = a.solve_dense(&rhs).unwrap();
        assert_eq!((x.rows(), x.cols()), (16, 3));
        let resid = matmul(&a.to_dense().unwrap(), &x).max_abs_diff(&rhs);
        assert!(resid < 1e-9, "solve_dense residual {resid}");
        // Row-count mismatch is a shape error.
        let bad = Matrix::zeros(8, 2);
        assert!(a.solve_dense(&bad).is_err());
    }

    #[test]
    fn solve_grid_mismatch_errors() {
        let s = session();
        let a = s.random_seeded(16, 4, 7).unwrap();
        let b = s.random_seeded(16, 8, 8).unwrap();
        assert!(a.solve(&b).is_err());
    }

    #[test]
    fn pseudo_inverse_equals_inverse_for_invertible_input() {
        let s = session();
        let m = s.random_spd(32, 8).unwrap();
        let pinv = m.pseudo_inverse().unwrap();
        // For invertible M, M⁺ = M⁻¹.
        let want = lu_inverse(&m.to_dense().unwrap()).unwrap();
        let diff = pinv.to_dense().unwrap().max_abs_diff(&want);
        assert!(diff < 1e-6, "pseudo-inverse vs serial inverse diff {diff}");
        // And it is a left inverse: M⁺·M ≈ I.
        let resid = m.inverse_residual(&pinv).unwrap();
        assert!(resid < 1e-8, "pseudo-inverse residual {resid}");
    }

    #[test]
    fn pseudo_inverse_transpose_is_cse_shared() {
        let s = session();
        let m = s.random_spd(16, 4).unwrap();
        let pinv = m.pseudo_inverse().unwrap();
        pinv.collect().unwrap();
        // Mᵀ feeds both the Gram product and the final product, but the
        // memoized plan runs the transpose stage exactly once.
        assert_eq!(s.metrics().method("transpose").unwrap().calls, 1);
        let text = pinv.explain().unwrap();
        assert!(text.contains("cache(transpose"), "{text}");
    }

    #[test]
    fn pseudo_inverse_with_lu_agrees_with_spin() {
        let s = session();
        let m = s.random_spd(16, 4).unwrap();
        let a = m.pseudo_inverse_with("spin").unwrap().to_dense().unwrap();
        let b = m.pseudo_inverse_with("lu").unwrap().to_dense().unwrap();
        assert!(a.max_abs_diff(&b) < 1e-8);
    }

    /// Every plan node of a handle's *canonical* (executed) DAG, walked
    /// through both the original nodes and their canonical memos.
    fn all_plan_nodes(m: &DistMatrix<'_>) -> Vec<crate::plan::MatExpr> {
        let cfg = m.session().optimizer_config();
        let mut out = Vec::new();
        let mut seen = std::collections::HashSet::new();
        let mut stack = vec![m.expr().clone()];
        while let Some(e) = stack.pop() {
            if !seen.insert(e.id()) {
                continue;
            }
            if let Some(canon) = e.canonical_for(cfg) {
                stack.push(canon);
            }
            stack.extend(e.children());
            out.push(e);
        }
        out
    }

    /// Satellite: evicting ANY subset of memoized plan-node values never
    /// changes a recomputed `collect()` result — n = 128 / block 16, with
    /// both built-in inversion schemes in the DAG.
    #[test]
    fn evicting_any_value_subset_preserves_results() {
        use crate::util::check::forall;
        for algo in ["spin", "lu"] {
            let s = session();
            // A DAG with real depth: Mᵀ, the Gram product, an invert and
            // the final thin product.
            let m = s.random_spd(128, 16).unwrap();
            let pinv = m.pseudo_inverse_with(algo).unwrap();
            let want = pinv.to_dense().unwrap();
            let nodes = all_plan_nodes(&pinv);
            assert!(nodes.len() >= 4, "expected a multi-node DAG for {algo}");
            forall(
                "eviction subsets preserve collect()",
                0xE0 + algo.len() as u64,
                6,
                |r| r.next_u64(),
                |&mask| {
                    for (i, node) in nodes.iter().enumerate() {
                        if mask & (1 << (i % 64)) != 0 {
                            node.evict_value();
                        }
                    }
                    let again = pinv.to_dense().map_err(|e| e.to_string())?;
                    if again.max_abs_diff(&want) == 0.0 {
                        Ok(())
                    } else {
                        Err(format!("{algo}: recompute after eviction diverged"))
                    }
                },
            );
        }
    }

    #[test]
    fn persist_pins_and_unpersist_releases() {
        let s = session();
        let a = s.random_seeded(16, 4, 40).unwrap();
        let b = s.random_seeded(16, 4, 41).unwrap();
        let prod = a.multiply(&b).unwrap();
        prod.persist().unwrap();
        let stats = s.cache_stats();
        assert!(stats.entries >= 1);
        assert!(stats.resident_bytes >= 16 * 16 * 8);
        // Pinned: a manual evict sweep of the canonical DAG must leave the
        // persisted root resident (the evictor checks the same flag).
        let canon = prod
            .expr()
            .canonical_for(s.optimizer_config())
            .expect("persist materialized, so the canonical memo exists");
        assert!(canon.is_pinned());
        assert!(canon.cached_value().is_some());
        // unpersist releases immediately and the handle still works.
        assert!(prod.unpersist().unwrap());
        assert!(canon.cached_value().is_none());
        assert!(!canon.is_pinned());
        assert!(!prod.unpersist().unwrap(), "second unpersist is a no-op");
        let d = prod.to_dense().unwrap();
        let want = crate::linalg::matmul(&a.to_dense().unwrap(), &b.to_dense().unwrap());
        assert!(d.max_abs_diff(&want) < 1e-11);
    }
}
