//! [`DistMatrix`]: a session-bound handle over a [`BlockMatrix`] whose
//! methods run on the owning session's cluster and backend.

use crate::blockmatrix::BlockMatrix;
use crate::error::{Result, SpinError};
use crate::linalg::{self, Matrix};
use crate::session::SpinSession;

/// A distributed square matrix bound to a [`SpinSession`].
///
/// Binary operations require both operands to share a block grid (the same
/// `nblocks` × `block_size` geometry); they do not need to come from the
/// same constructor. Handles borrow the session immutably, so any number of
/// them can be alive at once.
#[derive(Clone)]
pub struct DistMatrix<'s> {
    session: &'s SpinSession,
    inner: BlockMatrix,
}

impl<'s> DistMatrix<'s> {
    pub(crate) fn new(session: &'s SpinSession, inner: BlockMatrix) -> Self {
        DistMatrix { session, inner }
    }

    // ---------- geometry / access ----------

    /// Full matrix order `n`.
    pub fn n(&self) -> usize {
        self.inner.n()
    }

    /// Grid edge (the paper's split count `b`).
    pub fn nblocks(&self) -> usize {
        self.inner.nblocks()
    }

    pub fn block_size(&self) -> usize {
        self.inner.block_size()
    }

    /// The owning session.
    pub fn session(&self) -> &'s SpinSession {
        self.session
    }

    /// Borrow the underlying distributed matrix.
    pub fn block_matrix(&self) -> &BlockMatrix {
        &self.inner
    }

    /// Unwrap into the underlying distributed matrix.
    pub fn into_block_matrix(self) -> BlockMatrix {
        self.inner
    }

    /// Assemble into one dense matrix on the driver.
    pub fn to_dense(&self) -> Result<Matrix> {
        self.inner.to_dense()
    }

    // ---------- algebra ----------

    /// A⁻¹ with the session's default algorithm.
    pub fn inverse(&self) -> Result<DistMatrix<'s>> {
        self.session.invert(self)
    }

    /// A⁻¹ through a named registry entry (`"spin"`, `"lu"`, …).
    pub fn inverse_with(&self, algorithm: &str) -> Result<DistMatrix<'s>> {
        self.session.invert_with(algorithm, self)
    }

    /// C = A·B (distributed block matmul).
    pub fn multiply(&self, other: &DistMatrix<'_>) -> Result<DistMatrix<'s>> {
        let out = self.inner.multiply(
            self.session.cluster(),
            self.session.kernels(),
            other.block_matrix(),
        )?;
        Ok(DistMatrix::new(self.session, out))
    }

    /// C = A·B − D, fused: the subtraction runs inside the multiply's
    /// reduce stage (one shuffle total — the shape of SPIN's Schur step).
    pub fn multiply_sub(
        &self,
        other: &DistMatrix<'_>,
        d: &DistMatrix<'_>,
    ) -> Result<DistMatrix<'s>> {
        let out = self.inner.multiply_sub(
            self.session.cluster(),
            self.session.kernels(),
            other.block_matrix(),
            d.block_matrix(),
        )?;
        Ok(DistMatrix::new(self.session, out))
    }

    /// C = A − B.
    pub fn subtract(&self, other: &DistMatrix<'_>) -> Result<DistMatrix<'s>> {
        let out = self.inner.subtract(
            self.session.cluster(),
            self.session.kernels(),
            other.block_matrix(),
        )?;
        Ok(DistMatrix::new(self.session, out))
    }

    /// C = s·A.
    pub fn scalar_mul(&self, s: f64) -> Result<DistMatrix<'s>> {
        let out = self
            .inner
            .scalar_mul(self.session.cluster(), self.session.kernels(), s)?;
        Ok(DistMatrix::new(self.session, out))
    }

    /// Aᵀ (one distributed map).
    pub fn transpose(&self) -> DistMatrix<'s> {
        DistMatrix::new(self.session, self.inner.transpose(self.session.cluster()))
    }

    // ---------- solver workloads ----------

    /// Solve A·X = B for a distributed right-hand side: X = A⁻¹·B with the
    /// session's default inversion algorithm.
    pub fn solve(&self, rhs: &DistMatrix<'_>) -> Result<DistMatrix<'s>> {
        self.solve_with(self.session.default_algorithm(), rhs)
    }

    /// [`solve`](Self::solve) through a named registry entry.
    pub fn solve_with(&self, algorithm: &str, rhs: &DistMatrix<'_>) -> Result<DistMatrix<'s>> {
        self.inner.check_same_grid(rhs.block_matrix(), "solve")?;
        self.inverse_with(algorithm)?.multiply(rhs)
    }

    /// Solve A·X = B for a driver-side dense right-hand side (`n × k`,
    /// any `k` — the GLS / kriging shape). The inversion runs distributed;
    /// the final thin product runs on the driver.
    pub fn solve_dense(&self, rhs: &Matrix) -> Result<Matrix> {
        if rhs.rows() != self.n() {
            return Err(SpinError::shape(format!(
                "solve_dense: rhs has {} rows, matrix is {}x{}",
                rhs.rows(),
                self.n(),
                self.n()
            )));
        }
        let inv = self.inverse()?.to_dense()?;
        Ok(linalg::matmul(&inv, rhs))
    }

    /// Moore–Penrose pseudo-inverse M⁺ = (MᵀM)⁻¹·Mᵀ for full-column-rank
    /// input, with the session's default inversion algorithm.
    ///
    /// The Gram matrix MᵀM is symmetric positive definite whenever M has
    /// full column rank — exactly the input class the SPIN recursion is
    /// specified for. For an invertible M this equals M⁻¹ (a property the
    /// tests assert), but it is computed through the normal-equations
    /// pipeline, so it exercises `transpose` + `multiply` + inversion.
    pub fn pseudo_inverse(&self) -> Result<DistMatrix<'s>> {
        self.pseudo_inverse_with(self.session.default_algorithm())
    }

    /// [`pseudo_inverse`](Self::pseudo_inverse) through a named registry
    /// entry.
    pub fn pseudo_inverse_with(&self, algorithm: &str) -> Result<DistMatrix<'s>> {
        let mt = self.transpose();
        let gram = mt.multiply(self)?; //        MᵀM
        let gram_inv = gram.inverse_with(algorithm)?; // (MᵀM)⁻¹
        gram_inv.multiply(&mt) //               (MᵀM)⁻¹·Mᵀ
    }

    // ---------- checks ----------

    /// Relative inversion residual ‖A·X − I‖∞ / (‖A‖∞‖X‖∞·n) of a candidate
    /// inverse `x` against this matrix.
    pub fn inverse_residual(&self, x: &DistMatrix<'_>) -> Result<f64> {
        Ok(linalg::inverse_residual(&self.to_dense()?, &x.to_dense()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{lu_inverse, matmul};
    use crate::session::SpinSession;
    use crate::util::Rng;

    fn session() -> SpinSession {
        SpinSession::local(4).unwrap()
    }

    #[test]
    fn algebra_matches_dense() {
        let s = session();
        let a = s.random_seeded(16, 4, 1).unwrap();
        let b = s.random_seeded(16, 4, 2).unwrap();
        let (da, db) = (a.to_dense().unwrap(), b.to_dense().unwrap());
        assert!(
            a.multiply(&b)
                .unwrap()
                .to_dense()
                .unwrap()
                .max_abs_diff(&matmul(&da, &db))
                < 1e-11
        );
        assert!(
            a.subtract(&b)
                .unwrap()
                .to_dense()
                .unwrap()
                .max_abs_diff(&da.sub(&db).unwrap())
                < 1e-14
        );
        assert!(
            a.scalar_mul(-2.0)
                .unwrap()
                .to_dense()
                .unwrap()
                .max_abs_diff(&da.scale(-2.0))
                < 1e-14
        );
        assert!(
            a.transpose()
                .to_dense()
                .unwrap()
                .max_abs_diff(&da.transpose())
                < 1e-15
        );
    }

    #[test]
    fn multiply_sub_matches_composed_ops() {
        let s = session();
        let a = s.random_seeded(16, 4, 9).unwrap();
        let b = s.random_seeded(16, 4, 10).unwrap();
        let d = s.random_seeded(16, 4, 11).unwrap();
        let fused = a.multiply_sub(&b, &d).unwrap().to_dense().unwrap();
        let composed = a
            .multiply(&b)
            .unwrap()
            .subtract(&d)
            .unwrap()
            .to_dense()
            .unwrap();
        assert!(fused.max_abs_diff(&composed) < 1e-11);
    }

    #[test]
    fn solve_matches_serial_reference() {
        let s = session();
        let a = s.random_seeded(32, 8, 3).unwrap();
        let b = s.random_seeded(32, 8, 4).unwrap();
        let x = a.solve(&b).unwrap();
        // Reference: X = A⁻¹·B through the serial LU inverse.
        let want = matmul(
            &lu_inverse(&a.to_dense().unwrap()).unwrap(),
            &b.to_dense().unwrap(),
        );
        let diff = x.to_dense().unwrap().max_abs_diff(&want);
        assert!(diff < 1e-8, "solve diff {diff}");
        // Residual check: ‖A·X − B‖ small relative to ‖B‖.
        let ax = a.multiply(&x).unwrap().to_dense().unwrap();
        let resid = ax.max_abs_diff(&b.to_dense().unwrap()) / b.to_dense().unwrap().max_abs();
        assert!(resid < 1e-9, "solve residual {resid}");
    }

    #[test]
    fn solve_dense_rectangular_rhs() {
        let s = session();
        let a = s.random_seeded(16, 4, 5).unwrap();
        let mut rng = Rng::new(6);
        let rhs = Matrix::random_uniform(16, 3, -1.0, 1.0, &mut rng);
        let x = a.solve_dense(&rhs).unwrap();
        assert_eq!((x.rows(), x.cols()), (16, 3));
        let resid = matmul(&a.to_dense().unwrap(), &x).max_abs_diff(&rhs);
        assert!(resid < 1e-9, "solve_dense residual {resid}");
        // Row-count mismatch is a shape error.
        let bad = Matrix::zeros(8, 2);
        assert!(a.solve_dense(&bad).is_err());
    }

    #[test]
    fn solve_grid_mismatch_errors() {
        let s = session();
        let a = s.random_seeded(16, 4, 7).unwrap();
        let b = s.random_seeded(16, 8, 8).unwrap();
        assert!(a.solve(&b).is_err());
    }

    #[test]
    fn pseudo_inverse_equals_inverse_for_invertible_input() {
        let s = session();
        let m = s.random_spd(32, 8).unwrap();
        let pinv = m.pseudo_inverse().unwrap();
        // For invertible M, M⁺ = M⁻¹.
        let want = lu_inverse(&m.to_dense().unwrap()).unwrap();
        let diff = pinv.to_dense().unwrap().max_abs_diff(&want);
        assert!(diff < 1e-6, "pseudo-inverse vs serial inverse diff {diff}");
        // And it is a left inverse: M⁺·M ≈ I.
        let resid = m.inverse_residual(&pinv).unwrap();
        assert!(resid < 1e-8, "pseudo-inverse residual {resid}");
    }

    #[test]
    fn pseudo_inverse_with_lu_agrees_with_spin() {
        let s = session();
        let m = s.random_spd(16, 4).unwrap();
        let a = m.pseudo_inverse_with("spin").unwrap().to_dense().unwrap();
        let b = m.pseudo_inverse_with("lu").unwrap().to_dense().unwrap();
        assert!(a.max_abs_diff(&b) < 1e-8);
    }
}
