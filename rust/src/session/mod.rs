//! The session-scoped public API: one [`SpinSession`] owns the simulated
//! cluster, the block-kernel backend, the job defaults, and the
//! [`AlgorithmRegistry`] — callers stop hand-threading `Cluster`,
//! `&dyn BlockKernels`, `BlockMatrix`, and `JobConfig` through free
//! functions.
//!
//! ```no_run
//! use spin::session::SpinSession;
//!
//! fn main() -> spin::Result<()> {
//!     let session = SpinSession::builder().cores(4).build()?;
//!     let a = session.random_spd(256, 64)?;
//!     let inv = a.inverse()?;                 // SPIN by default
//!     let lu = session.invert_with("lu", &a)?; // any registered scheme
//!     assert!(a.inverse_residual(&inv)? < 1e-10);
//!     assert!(a.inverse_residual(&lu)? < 1e-10);
//!     Ok(())
//! }
//! ```
//!
//! Matrix handles ([`DistMatrix`]) are borrowed from the session and are
//! **lazy**: operator methods (`inverse`, `multiply`, `multiply_sub`,
//! `solve`, `pseudo_inverse`, …) build a [`crate::plan::MatExpr`] DAG and
//! return immediately. Materialization points (`collect`, `to_dense`,
//! `inverse_residual`, `solve_dense`, `block_matrix`) run the plan
//! optimizer — multiply+subtract fusion, transpose pushdown, scalar
//! folding, CSE with automatic cache insertion — and lower the optimized
//! plan onto the session's cluster, attributing per-plan-node metrics to
//! its registry. [`DistMatrix::explain`] / [`SpinSession::explain_invert`]
//! print the optimized plan with predicted shuffle stages per node.
//!
//! Handles stay grid-partitioned across operations (the cluster's
//! partitioner contract), so chained calls never re-shuffle for alignment
//! and never round-trip the driver — `session.metrics().driver_collects()`
//! stays 0 and per-method `shuffle_bytes`/`shuffle_stages` expose what
//! each op really moved.

mod handle;

pub use handle::DistMatrix;

pub use crate::algos::{AlgorithmRegistry, InversionAlgorithm};

use std::path::PathBuf;
use std::sync::Arc;

use crate::analysis::{self, AlgoModel, AnalysisContext, PlanVerdict};
use crate::blockmatrix::{Block, BlockMatrix};
use crate::cluster::{Cluster, MetricsSnapshot};
use crate::config::{BackendKind, ClusterConfig, GeneratorKind, JobConfig, LeafMethod};
use crate::error::{Result, SpinError};
use crate::linalg::Matrix;
use crate::plan::{
    render_plan_sized, CacheManager, CacheStats, MatExpr, Optimizer, OptimizerConfig, PlanExec,
    SourceSpec,
};
use crate::runtime::{make_backend, BlockKernels};

/// Per-session job parameters applied to every operation (a [`JobConfig`]
/// minus the per-matrix geometry, which comes from the handle).
#[derive(Debug, Clone)]
struct JobDefaults {
    seed: u64,
    generator: GeneratorKind,
    leaf: LeafMethod,
    fuse_leaf_2x2: bool,
    residual_check: bool,
    tolerance: f64,
    max_iters: usize,
}

impl Default for JobDefaults {
    fn default() -> Self {
        // Single source of truth for defaults: JobConfig::new.
        let j = JobConfig::new(2, 1);
        JobDefaults {
            seed: j.seed,
            generator: j.generator,
            leaf: j.leaf,
            fuse_leaf_2x2: j.fuse_leaf_2x2,
            residual_check: j.residual_check,
            tolerance: j.tolerance,
            max_iters: j.max_iters,
        }
    }
}

/// Builder for [`SpinSession`]. Obtain via [`SpinSession::builder`].
pub struct SessionBuilder {
    cluster: ClusterConfig,
    defaults: JobDefaults,
    registry: AlgorithmRegistry,
    default_algo: String,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        SessionBuilder {
            cluster: ClusterConfig::local(4),
            defaults: JobDefaults::default(),
            registry: AlgorithmRegistry::with_defaults(),
            default_algo: "spin".to_string(),
        }
    }
}

impl SessionBuilder {
    /// Swap in a topology preset, keeping the orthogonal knobs
    /// (backend, artifacts dir, worker threads) that may have been set
    /// before or after on the builder. Network/virtual-time come from the
    /// preset.
    fn topology(mut self, preset: ClusterConfig) -> Self {
        let backend = self.cluster.backend;
        let artifacts = self.cluster.artifacts_dir.clone();
        let workers = self.cluster.worker_threads;
        self.cluster = preset;
        self.cluster.backend = backend;
        self.cluster.artifacts_dir = artifacts;
        self.cluster.worker_threads = workers;
        self
    }

    /// Local single-node cluster with `cores` task slots.
    pub fn cores(self, cores: usize) -> Self {
        self.topology(ClusterConfig::local(cores))
    }

    /// The paper's testbed topology (3 nodes × 2 executors × 5 cores).
    pub fn paper_cluster(self) -> Self {
        self.topology(ClusterConfig::paper())
    }

    /// Replace the whole cluster topology (overrides `cores`/`backend`
    /// calls made so far).
    pub fn cluster_config(mut self, cfg: ClusterConfig) -> Self {
        self.cluster = cfg;
        self
    }

    /// Which block-kernel backend executes leaf/block compute.
    pub fn backend(mut self, kind: BackendKind) -> Self {
        self.cluster.backend = kind;
        self
    }

    /// Where AOT artifacts live (Xla backend).
    pub fn artifacts_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.cluster.artifacts_dir = dir.into();
        self
    }

    /// Real worker threads chewing through tasks on this host.
    pub fn worker_threads(mut self, n: usize) -> Self {
        self.cluster.worker_threads = n;
        self
    }

    /// Seed for `random` matrix generation.
    pub fn seed(mut self, seed: u64) -> Self {
        self.defaults.seed = seed;
        self
    }

    /// Test-matrix family for `random` generation.
    pub fn generator(mut self, generator: GeneratorKind) -> Self {
        self.defaults.generator = generator;
        self
    }

    /// Serial method used on leaf blocks.
    pub fn leaf(mut self, leaf: LeafMethod) -> Self {
        self.defaults.leaf = leaf;
        self
    }

    /// Fuse the 2×2-grid recursion base into one kernel (our extension).
    pub fn fuse_leaf_2x2(mut self, on: bool) -> Self {
        self.defaults.fuse_leaf_2x2 = on;
        self
    }

    /// Verify ‖A·A⁻¹ − I‖∞ after every inversion.
    pub fn residual_check(mut self, on: bool) -> Self {
        self.defaults.residual_check = on;
        self
    }

    /// Convergence tolerance for iterative schemes (`newton`): stop once
    /// ‖I − A·Xₖ‖∞ ≤ `tolerance`. Exact schemes ignore it.
    pub fn tolerance(mut self, tolerance: f64) -> Self {
        self.defaults.tolerance = tolerance;
        self
    }

    /// Iteration budget for iterative schemes (`newton`). When the budget
    /// is exhausted before the tolerance is met, the best iterate so far
    /// is returned with `converged = false` in its
    /// [`crate::cluster::ConvergenceReport`].
    pub fn max_iters(mut self, max_iters: usize) -> Self {
        self.defaults.max_iters = max_iters;
        self
    }

    /// Copy seed/generator/leaf/fusion/residual settings from an existing
    /// [`JobConfig`] (geometry still comes from each matrix handle).
    pub fn job_defaults(mut self, job: &JobConfig) -> Self {
        self.defaults = JobDefaults {
            seed: job.seed,
            generator: job.generator,
            leaf: job.leaf,
            fuse_leaf_2x2: job.fuse_leaf_2x2,
            residual_check: job.residual_check,
            tolerance: job.tolerance,
            max_iters: job.max_iters,
        };
        self
    }

    /// Register an extra inversion scheme (errors on duplicate names).
    pub fn register_algorithm(mut self, algo: Arc<dyn InversionAlgorithm>) -> Result<Self> {
        self.registry.register(algo)?;
        Ok(self)
    }

    /// Which registered algorithm `DistMatrix::inverse` uses
    /// (default `spin`). Validated at [`build`](Self::build).
    pub fn default_algorithm(mut self, name: &str) -> Self {
        self.default_algo = name.to_string();
        self
    }

    /// Validate and assemble the session (instantiates the backend, so an
    /// Xla session without artifacts fails here, not mid-job).
    pub fn build(self) -> Result<SpinSession> {
        self.cluster.validate()?;
        if !self.registry.contains(&self.default_algo) {
            return Err(SpinError::config(format!(
                "default algorithm `{}` is not registered (registered: {})",
                self.default_algo,
                self.registry.names().join("|")
            )));
        }
        let kernels = make_backend(&self.cluster)?;
        let lifecycle = Arc::new(CacheManager::new(self.cluster.cache_budget_bytes));
        Ok(SpinSession {
            cluster: Cluster::new(self.cluster),
            kernels,
            defaults: self.defaults,
            registry: self.registry,
            default_algo: self.default_algo,
            lifecycle,
        })
    }
}

/// A long-lived context owning the cluster, the backend, the job defaults,
/// and the algorithm registry. Hands out [`DistMatrix`] handles bound to
/// its lifetime.
pub struct SpinSession {
    cluster: Cluster,
    kernels: Box<dyn BlockKernels>,
    defaults: JobDefaults,
    registry: AlgorithmRegistry,
    default_algo: String,
    /// Value-lifecycle registry: tracks every materialized plan-node
    /// value, enforces `ClusterConfig::cache_budget_bytes` by LRU
    /// eviction, and honors `DistMatrix::persist` pins.
    lifecycle: Arc<CacheManager>,
}

impl SpinSession {
    /// Start configuring a session.
    pub fn builder() -> SessionBuilder {
        SessionBuilder::default()
    }

    /// Shorthand: a local `cores`-slot session with native kernels.
    pub fn local(cores: usize) -> Result<SpinSession> {
        SpinSession::builder().cores(cores).build()
    }

    // ---------- matrix constructors ----------

    /// Random distributed matrix per the session's generator/seed defaults.
    pub fn random(&self, n: usize, block_size: usize) -> Result<DistMatrix<'_>> {
        self.random_seeded(n, block_size, self.defaults.seed)
    }

    /// Random distributed matrix with an explicit seed.
    pub fn random_seeded(&self, n: usize, block_size: usize, seed: u64) -> Result<DistMatrix<'_>> {
        let mut job = self.job_for(n, block_size);
        job.seed = seed;
        Ok(self.wrap(BlockMatrix::random(&job)?))
    }

    /// Random symmetric-positive-definite distributed matrix (the paper's
    /// stated input scope).
    pub fn random_spd(&self, n: usize, block_size: usize) -> Result<DistMatrix<'_>> {
        let mut job = self.job_for(n, block_size);
        job.generator = GeneratorKind::Spd;
        Ok(self.wrap(BlockMatrix::random(&job)?))
    }

    /// **Lazy** random distributed matrix: the handle returns in O(1) —
    /// no block exists until the first materialization, which produces
    /// them per-partition on the workers. Bit-identical to
    /// [`random_seeded`](Self::random_seeded) for the same parameters
    /// (both paths evaluate the same per-block generator function), so
    /// callers can switch freely as input sizes grow.
    pub fn lazy_random_seeded(
        &self,
        n: usize,
        block_size: usize,
        seed: u64,
    ) -> Result<DistMatrix<'_>> {
        let mut job = self.job_for(n, block_size);
        job.seed = seed;
        job.validate()?;
        Ok(self.wrap_expr(MatExpr::lazy_source(SourceSpec::Generated {
            n,
            block_size,
            seed,
            generator: job.generator,
        })?))
    }

    /// A matrix stored in a block-store directory (`spin ingest` /
    /// [`crate::store::LocalDirStore`]), as a lazy handle: only
    /// `meta.json` is read here; block files are read per-partition on
    /// the workers at first materialization.
    pub fn from_store(&self, dir: impl Into<PathBuf>) -> Result<DistMatrix<'_>> {
        Ok(self.wrap_expr(MatExpr::lazy_source(SourceSpec::from_dir(dir)?)?))
    }

    /// Split a driver-side dense matrix into session-managed blocks.
    pub fn from_dense(&self, dense: &Matrix, block_size: usize) -> Result<DistMatrix<'_>> {
        Ok(self.wrap(BlockMatrix::from_dense(dense, block_size)?))
    }

    /// Wrap pre-built blocks (validates the grid like
    /// [`BlockMatrix::from_blocks`]).
    pub fn from_blocks(
        &self,
        blocks: Vec<Block>,
        nblocks: usize,
        block_size: usize,
    ) -> Result<DistMatrix<'_>> {
        Ok(self.wrap(BlockMatrix::from_blocks(blocks, nblocks, block_size)?))
    }

    /// Distributed identity.
    pub fn identity(&self, n: usize, block_size: usize) -> Result<DistMatrix<'_>> {
        Ok(self.wrap(BlockMatrix::identity(n, block_size)?))
    }

    /// Bind an existing [`BlockMatrix`] to this session (a plan source).
    pub fn wrap(&self, matrix: BlockMatrix) -> DistMatrix<'_> {
        self.wrap_expr(MatExpr::source(matrix))
    }

    /// Bind a lazy expression to this session.
    pub fn wrap_expr(&self, expr: MatExpr) -> DistMatrix<'_> {
        DistMatrix::new(self, expr)
    }

    // ---------- algorithm dispatch ----------

    /// A⁻¹ through a named registry entry, as a lazy plan node. The name
    /// is validated now (unknown schemes fail immediately); the inversion
    /// itself runs when the returned handle is materialized.
    pub fn invert_with(&self, algorithm: &str, m: &DistMatrix<'_>) -> Result<DistMatrix<'_>> {
        self.registry.get(algorithm)?; // fail fast on unknown names
        Ok(self.wrap_expr(m.expr().invert(algorithm)))
    }

    /// Invert with the session's default algorithm (lazy).
    pub fn invert(&self, m: &DistMatrix<'_>) -> Result<DistMatrix<'_>> {
        self.invert_with(&self.default_algo, m)
    }

    // ---------- plan evaluation / explain ----------

    /// The optimizer configuration implied by the cluster's
    /// `plan_optimizer` knob.
    pub fn optimizer_config(&self) -> OptimizerConfig {
        OptimizerConfig::from_cluster(self.cluster.config())
    }

    /// Materialize a plan on this session's cluster: optimize, lower onto
    /// the block ops, resolve `invert` nodes through the algorithm
    /// registry. Memoized per plan node — re-materializing is free until
    /// the LRU evictor (or `unpersist`) releases a value, after which it
    /// recomputes bit-identically.
    pub(crate) fn materialize(&self, expr: &MatExpr) -> Result<BlockMatrix> {
        let exec =
            PlanExec::new(&self.cluster, self.kernels.as_ref()).with_lifecycle(&self.lifecycle);
        exec.eval_with(
            expr,
            &|algo: &str, opts: &crate::plan::InvertOpts, m: &BlockMatrix| {
                let scheme = self.registry.get(algo)?;
                let mut job = self.job_for(m.n(), m.block_size());
                if let Some(tol) = opts.tolerance {
                    job.tolerance = tol;
                }
                if let Some(iters) = opts.max_iters {
                    job.max_iters = iters;
                }
                scheme.invert(&self.cluster, self.kernels.as_ref(), m, &job)
            },
        )
    }

    /// Canonical (optimizer-output) form of an expression — the node the
    /// executor actually memoizes values on, hence the pin/evict target.
    fn canonical(&self, expr: &MatExpr) -> Result<MatExpr> {
        let _gate = self.lifecycle.optimize_gate();
        Optimizer::new(self.optimizer_config()).optimize(expr)
    }

    /// Pin an expression's materialized value against LRU eviction
    /// (engine behind [`DistMatrix::persist`]). The value must already be
    /// materialized by the caller. Pinned bytes are excluded from the LRU
    /// budget and surfaced in `MetricsSnapshot::pinned_bytes`.
    pub(crate) fn pin_expr(&self, expr: &MatExpr) -> Result<()> {
        self.canonical(expr)?.set_pinned(true);
        self.cluster.set_pinned_bytes(self.lifecycle.stats().pinned_bytes);
        Ok(())
    }

    /// Unpin and immediately release an expression's materialized value
    /// (engine behind [`DistMatrix::unpersist`]). Returns whether a value
    /// was actually resident.
    pub(crate) fn unpin_expr(&self, expr: &MatExpr) -> Result<bool> {
        let canonical = self.canonical(expr)?;
        canonical.set_pinned(false);
        let released = canonical.evict_value();
        self.lifecycle.forget(canonical.id());
        self.cluster.set_pinned_bytes(self.lifecycle.stats().pinned_bytes);
        Ok(released)
    }

    /// Lifecycle bookkeeping: resident bytes, entry count, budget, and
    /// eviction totals of this session's value cache.
    pub fn cache_stats(&self) -> CacheStats {
        self.lifecycle.stats()
    }

    /// Render the optimized form of an expression (the engine behind
    /// [`DistMatrix::explain`]).
    pub(crate) fn explain_expr(&self, expr: &MatExpr) -> Result<String> {
        self.explain_expr_sized(expr, None)
    }

    /// [`explain_expr`](Self::explain_expr) with an explicit payload
    /// block size for the resident-bytes column (used when the plan is
    /// rendered over unit-block shape sources).
    pub(crate) fn explain_expr_sized(
        &self,
        expr: &MatExpr,
        block_size: Option<usize>,
    ) -> Result<String> {
        let optimized = self.canonical(expr)?;
        let mut out = format!(
            "optimized plan ({} nodes -> {}, optimizer {}):\n",
            expr.node_count(),
            optimized.node_count(),
            if self.config().plan_optimizer { "on" } else { "off" },
        );
        out.push_str(&render_plan_sized(
            &optimized,
            self.config().partitioner_aware,
            block_size,
        ));
        Ok(out)
    }

    /// Print one optimized recursion level of `algorithm` at the given
    /// geometry — the session-level `explain()` behind `spin explain`.
    /// Algorithms that expose no plan render as a single opaque `invert`
    /// node.
    pub fn explain_invert(&self, algorithm: &str, n: usize, block_size: usize) -> Result<String> {
        let scheme = self.registry.get(algorithm)?;
        if block_size == 0 || n == 0 || n % block_size != 0 {
            return Err(SpinError::shape(format!(
                "explain: block size {block_size} does not divide n {n}"
            )));
        }
        // The plan's shape depends only on the grid, so render over a
        // unit-block zero source — explaining n = 65536 must not allocate
        // an n×n matrix.
        let src = MatExpr::source(BlockMatrix::zeros(n / block_size, 1)?);
        let plan = match scheme.plan(&src)? {
            Some(p) => p,
            None => src.invert(algorithm),
        };
        let mut out = format!(
            "{algorithm}: one recursion level at n = {n}, grid {b}x{b} of {block_size}x{block_size}\n",
            b = n / block_size,
        );
        // Resident-bytes predictions use the real block size even though
        // the shape plan is built over unit blocks.
        out.push_str(&self.explain_expr_sized(&plan, Some(block_size))?);
        // Iterative schemes render one iteration's plan; annotate the
        // driver-side convergence loop wrapped around it.
        if let Some(note) = scheme.convergence_note() {
            out.push_str(&note);
            if !note.ends_with('\n') {
                out.push('\n');
            }
        }
        Ok(out)
    }

    // ---------- static plan verification ----------

    /// Run the static plan verifier (see [`crate::analysis`]) on an
    /// expression without executing it: prove geometry/partitioner
    /// propagation, derive the exchange-stage/shuffle-byte cost profile
    /// (unfolding recursive `invert` nodes through the registry's
    /// published [`AlgoModel`]s), diff the optimized plan against the raw
    /// plan for rewrite soundness, and prove the eviction-closure
    /// contract.
    pub fn analyze_expr(&self, expr: &MatExpr) -> Result<PlanVerdict> {
        let optimized = self.canonical(expr)?;
        let aware = self.config().partitioner_aware;
        let resolve = |name: &str| -> Option<AlgoModel> {
            self.registry.get(name).ok().and_then(|s| s.analysis_model())
        };
        let ctx = AnalysisContext {
            resolve: &resolve,
            optimizer: self.optimizer_config(),
            partitioner_aware: aware,
            default_max_iters: self.defaults.max_iters,
        };
        let verdict = PlanVerdict {
            analysis: analysis::analyze_plan(&optimized, &ctx)?,
            rewrite_violations: analysis::rewrite_soundness(expr, &optimized, aware),
            lifecycle: analysis::lifecycle_soundness(&optimized),
        };
        Ok(verdict)
    }

    /// [`analyze_expr`](Self::analyze_expr) for one named inversion at a
    /// given geometry, without touching matrix data: the plan is built
    /// over a lazily-generated source spec, so linting n = 65536 is as
    /// cheap as linting n = 64. The engine behind `spin lint` and
    /// `spin explain --verify`.
    pub fn analyze_invert(
        &self,
        algorithm: &str,
        n: usize,
        block_size: usize,
    ) -> Result<PlanVerdict> {
        self.registry.get(algorithm)?; // fail fast on unknown names
        if block_size == 0 || n == 0 || n % block_size != 0 {
            return Err(SpinError::shape(format!(
                "analyze: block size {block_size} does not divide n {n}"
            )));
        }
        let src = MatExpr::lazy_source(SourceSpec::Generated {
            n,
            block_size,
            seed: self.defaults.seed,
            generator: self.defaults.generator,
        })?;
        self.analyze_expr(&src.invert(algorithm))
    }

    /// Register an extra inversion scheme after construction.
    pub fn register_algorithm(&mut self, algo: Arc<dyn InversionAlgorithm>) -> Result<()> {
        self.registry.register(algo)
    }

    /// Sorted names of the registered inversion schemes.
    pub fn algorithms(&self) -> Vec<String> {
        self.registry.names()
    }

    /// Name used by [`DistMatrix::inverse`].
    pub fn default_algorithm(&self) -> &str {
        &self.default_algo
    }

    /// The registry itself (for introspection / descriptions).
    pub fn registry(&self) -> &AlgorithmRegistry {
        &self.registry
    }

    // ---------- infrastructure accessors ----------

    /// The simulated cluster every handle's operations run on.
    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    /// The block-kernel backend.
    pub fn kernels(&self) -> &dyn BlockKernels {
        self.kernels.as_ref()
    }

    /// The cluster topology this session was built from.
    pub fn config(&self) -> &ClusterConfig {
        self.cluster.config()
    }

    /// Backend name (`native` / `xla`).
    pub fn backend_name(&self) -> &'static str {
        self.kernels.name()
    }

    /// Virtual wall-clock seconds consumed so far.
    pub fn virtual_secs(&self) -> f64 {
        self.cluster.virtual_secs()
    }

    /// Per-method metrics snapshot. Refreshes the pinned-bytes gauge
    /// first, so values whose DAGs died since the last pin change (freed
    /// by ref-counting, not by `unpersist`) don't read as still pinned.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.cluster.set_pinned_bytes(self.lifecycle.stats().pinned_bytes);
        self.cluster.metrics()
    }

    /// Reset the virtual clock + metrics (new measurement window).
    pub fn reset_clock(&self) {
        self.cluster.reset();
    }

    /// A full [`JobConfig`] for the given geometry under this session's
    /// defaults.
    pub fn job_for(&self, n: usize, block_size: usize) -> JobConfig {
        let mut job = JobConfig::new(n, block_size);
        job.seed = self.defaults.seed;
        job.generator = self.defaults.generator;
        job.leaf = self.defaults.leaf;
        job.fuse_leaf_2x2 = self.defaults.fuse_leaf_2x2;
        job.residual_check = self.defaults.residual_check;
        job.tolerance = self.defaults.tolerance;
        job.max_iters = self.defaults.max_iters;
        job
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::JobConfig;

    #[test]
    fn builder_smoke() {
        let session = SpinSession::builder()
            .cores(4)
            .backend(BackendKind::Native)
            .build()
            .unwrap();
        assert_eq!(session.backend_name(), "native");
        assert_eq!(session.config().total_cores(), 4);
        assert_eq!(session.default_algorithm(), "spin");
        assert_eq!(
            session.algorithms(),
            vec![
                "cholesky".to_string(),
                "lu".to_string(),
                "newton".to_string(),
                "spin".to_string()
            ]
        );
    }

    #[test]
    fn topology_presets_keep_orthogonal_knobs_in_any_order() {
        let s = SpinSession::builder()
            .worker_threads(3)
            .cores(4)
            .build()
            .unwrap();
        assert_eq!(s.config().worker_threads, 3);
        assert_eq!(s.config().total_cores(), 4);
        let s = SpinSession::builder()
            .artifacts_dir("custom-artifacts")
            .paper_cluster()
            .build()
            .unwrap();
        assert_eq!(
            s.config().artifacts_dir,
            std::path::PathBuf::from("custom-artifacts")
        );
        assert_eq!(s.config().total_cores(), 30);
    }

    #[test]
    fn unknown_default_algorithm_fails_at_build() {
        let err = SpinSession::builder()
            .default_algorithm("qr")
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("qr"), "{err}");
        assert!(
            err.to_string().contains("cholesky|lu|newton|spin"),
            "unknown-algo errors list the registered names: {err}"
        );
    }

    #[test]
    fn invert_default_and_named() {
        let session = SpinSession::local(4).unwrap();
        let a = session.random(32, 8).unwrap();
        let spin = a.inverse().unwrap();
        let lu = session.invert_with("lu", &a).unwrap();
        assert!(a.inverse_residual(&spin).unwrap() < 1e-10);
        assert!(a.inverse_residual(&lu).unwrap() < 1e-10);
        assert!(session.invert_with("qr", &a).is_err());
    }

    #[test]
    fn job_defaults_copied_from_job_config() {
        let mut job = JobConfig::new(64, 16);
        job.seed = 99;
        job.generator = GeneratorKind::Spd;
        job.leaf = LeafMethod::GaussJordan;
        job.residual_check = true;
        let session = SpinSession::builder()
            .cores(2)
            .job_defaults(&job)
            .build()
            .unwrap();
        let round_trip = session.job_for(64, 16);
        assert_eq!(round_trip, job);
    }

    #[test]
    fn random_is_deterministic_per_seed() {
        let session = SpinSession::local(2).unwrap();
        let a = session.random_seeded(16, 4, 7).unwrap().to_dense().unwrap();
        let b = session.random_seeded(16, 4, 7).unwrap().to_dense().unwrap();
        let c = session.random_seeded(16, 4, 8).unwrap().to_dense().unwrap();
        assert_eq!(a.max_abs_diff(&b), 0.0);
        assert!(a.max_abs_diff(&c) > 0.0);
    }

    #[test]
    fn session_residual_check_propagates() {
        // With residual_check on, a well-conditioned input still succeeds —
        // the check runs inside the algorithm (exercised by unit tests of
        // the spin module for the failure path). `collect` is the
        // materialization point where the algorithm actually runs.
        let session = SpinSession::builder()
            .cores(2)
            .residual_check(true)
            .build()
            .unwrap();
        let a = session.random(16, 4).unwrap();
        assert!(a.inverse().unwrap().collect().is_ok());
    }

    #[test]
    fn custom_algorithm_via_builder() {
        struct NegatedSpin;
        impl InversionAlgorithm for NegatedSpin {
            fn name(&self) -> &str {
                "negated-twice"
            }
            fn invert(
                &self,
                cluster: &Cluster,
                kernels: &dyn BlockKernels,
                a: &BlockMatrix,
                job: &JobConfig,
            ) -> Result<BlockMatrix> {
                // (−A)⁻¹ · (−1) == A⁻¹: exercises a composite scheme.
                let neg = a.scalar_mul(cluster, kernels, -1.0)?;
                let inv = crate::algos::SpinAlgorithm.invert(cluster, kernels, &neg, job)?;
                inv.scalar_mul(cluster, kernels, -1.0)
            }
        }
        let session = SpinSession::builder()
            .cores(2)
            .register_algorithm(Arc::new(NegatedSpin))
            .unwrap()
            .default_algorithm("negated-twice")
            .build()
            .unwrap();
        let a = session.random(16, 4).unwrap();
        let inv = a.inverse().unwrap();
        assert!(a.inverse_residual(&inv).unwrap() < 1e-10);
    }

    #[test]
    fn explain_invert_shows_fusion_and_cse_cache() {
        let session = SpinSession::local(2).unwrap();
        let text = session.explain_invert("spin", 256, 32).unwrap();
        // The Schur step is fused by the optimizer…
        assert!(text.contains("multiply_sub"), "{text}");
        // …and the shared intermediates (I, III, VI) are cache points.
        assert!(text.contains("cache("), "{text}");
        assert!(text.contains("invert[spin]"), "{text}");
        assert!(text.contains("exchange stage"), "{text}");
        // Unknown algorithms fail fast; LU exposes no plan and renders as
        // one opaque invert node.
        assert!(session.explain_invert("qr", 64, 16).is_err());
        let lu = session.explain_invert("lu", 64, 16).unwrap();
        assert!(lu.contains("invert[lu]"), "{lu}");
        // Bad geometry errors.
        assert!(session.explain_invert("spin", 64, 48).is_err());
    }

    #[test]
    fn explain_renders_iterative_and_cholesky_plans() {
        let session = SpinSession::local(2).unwrap();
        // Newton renders one iteration's plan plus the convergence-loop
        // annotation (the driver loop is not itself a plan node).
        let newton = session.explain_invert("newton", 64, 16).unwrap();
        assert!(newton.contains("convergence loop"), "{newton}");
        assert!(newton.contains("tolerance"), "{newton}");
        assert!(newton.contains("multiply"), "{newton}");
        // Cholesky exposes its recursion level: two self-referential
        // invert nodes, the L21 product, and the Schur subtraction.
        let chol = session.explain_invert("cholesky", 64, 16).unwrap();
        assert!(chol.contains("invert[cholesky]"), "{chol}");
        assert!(chol.contains("subtract"), "{chol}");
        // Exact schemes carry no convergence annotation.
        assert!(!chol.contains("convergence loop"), "{chol}");
    }

    #[test]
    fn builder_iterative_knobs_reach_job_for() {
        let s = SpinSession::builder()
            .cores(2)
            .tolerance(1e-6)
            .max_iters(9)
            .build()
            .unwrap();
        let job = s.job_for(32, 8);
        assert_eq!(job.tolerance, 1e-6);
        assert_eq!(job.max_iters, 9);
    }

    #[test]
    fn explain_respects_plan_optimizer_toggle() {
        let mut cfg = ClusterConfig::local(2);
        cfg.plan_optimizer = false;
        let session = SpinSession::builder().cluster_config(cfg).build().unwrap();
        let text = session.explain_invert("spin", 64, 16).unwrap();
        assert!(text.contains("optimizer off"), "{text}");
        assert!(!text.contains("multiply_sub"), "unfused plan: {text}");
    }

    #[test]
    fn cache_budget_evicts_and_results_stay_correct() {
        let mut cfg = ClusterConfig::local(2);
        // Budget = one 64x64 value; the pseudo-inverse pipeline holds four
        // intermediates, so the LRU evictor must fire.
        cfg.cache_budget_bytes = 64 * 64 * 8;
        let s = SpinSession::builder().cluster_config(cfg).build().unwrap();
        let m = s.random_spd(64, 16).unwrap();
        let pinv = m.pseudo_inverse().unwrap();
        let d1 = pinv.to_dense().unwrap();
        assert!(s.metrics().cache_evictions() > 0, "budget must evict");
        assert!(s.metrics().cache_evicted_bytes() > 0);
        let stats = s.cache_stats();
        assert_eq!(stats.budget_bytes, Some(64 * 64 * 8));
        assert!(stats.resident_bytes <= 64 * 64 * 8);
        assert!(stats.evictions > 0);
        // Re-reads (memoized or recomputed) are bit-identical.
        let d2 = pinv.to_dense().unwrap();
        assert_eq!(d1.max_abs_diff(&d2), 0.0);
    }

    #[test]
    fn unlimited_budget_never_evicts() {
        let s = SpinSession::local(2).unwrap();
        let m = s.random_spd(64, 16).unwrap();
        let pinv = m.pseudo_inverse().unwrap();
        pinv.collect().unwrap();
        assert_eq!(s.metrics().cache_evictions(), 0);
        assert_eq!(s.cache_stats().budget_bytes, None);
        assert!(s.cache_stats().entries >= 4);
    }

    #[test]
    fn lazy_random_is_bit_identical_to_eager_and_deferred() {
        let session = SpinSession::local(2).unwrap();
        session.reset_clock();
        let lazy = session.lazy_random_seeded(32, 8, 77).unwrap();
        assert_eq!(
            session.metrics().stages().len(),
            0,
            "lazy handle construction must not execute"
        );
        let eager = session.random_seeded(32, 8, 77).unwrap();
        assert_eq!(
            lazy.to_dense()
                .unwrap()
                .max_abs_diff(&eager.to_dense().unwrap()),
            0.0,
            "lazy and eager generation share one per-block function"
        );
        assert_eq!(session.metrics().method("generate").unwrap().calls, 1);
        // Bad geometry is rejected at handle construction.
        assert!(session.lazy_random_seeded(100, 10, 1).is_err());
    }

    #[test]
    fn session_from_store_reads_blocks_at_materialization() {
        let dir = std::env::temp_dir().join(format!("spin_sess_store_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut job = JobConfig::new(16, 4);
        job.seed = 3;
        let store = crate::store::LocalDirStore::create(&dir, 4, 4).unwrap();
        crate::store::ingest_generated(&store, &job).unwrap();
        let session = SpinSession::local(2).unwrap();
        let m = session.from_store(&dir).unwrap();
        assert_eq!((m.n(), m.block_size()), (16, 4));
        let want = session.random_seeded(16, 4, 3).unwrap().to_dense().unwrap();
        assert_eq!(m.to_dense().unwrap().max_abs_diff(&want), 0.0);
        assert!(session.metrics().method("loadBlock").unwrap().calls >= 1);
        assert!(session.from_store("/definitely/missing").is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn store_re_ingest_is_detected_not_silently_mixed() {
        let dir = std::env::temp_dir().join(format!("spin_reingest_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut job = JobConfig::new(16, 4);
        job.seed = 1;
        let store = crate::store::LocalDirStore::create(&dir, 4, 4).unwrap();
        crate::store::ingest_generated(&store, &job).unwrap();
        let session = SpinSession::local(2).unwrap();
        let m = session.from_store(&dir).unwrap();
        let first = m.to_dense().unwrap();
        // Re-ingest IN PLACE with different data (new store generation).
        job.seed = 2;
        let store = crate::store::LocalDirStore::create(&dir, 4, 4).unwrap();
        crate::store::ingest_generated(&store, &job).unwrap();
        // The memoized value is still served (consistent with the plan)…
        assert_eq!(m.to_dense().unwrap().max_abs_diff(&first), 0.0);
        // …but once evicted, re-materialization must fail loudly rather
        // than regenerate DIFFERENT bytes under the same plan node.
        assert!(m.expr().evict_value());
        let err = m.to_dense().unwrap_err().to_string();
        assert!(err.contains("changed since this plan was built"), "{err}");
        // A fresh handle against the current store works.
        let fresh = session.from_store(&dir).unwrap();
        assert!(fresh.to_dense().unwrap().max_abs_diff(&first) > 0.0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn persist_updates_pinned_bytes_gauge() {
        let s = SpinSession::local(2).unwrap();
        let a = s.random_seeded(16, 4, 50).unwrap();
        let b = s.random_seeded(16, 4, 51).unwrap();
        let prod = a.multiply(&b).unwrap();
        assert_eq!(s.metrics().pinned_bytes(), 0);
        prod.persist().unwrap();
        assert_eq!(s.metrics().pinned_bytes(), 16 * 16 * 8);
        assert_eq!(s.cache_stats().pinned_bytes, 16 * 16 * 8);
        prod.unpersist().unwrap();
        assert_eq!(s.metrics().pinned_bytes(), 0);
    }

    #[test]
    fn wrap_and_from_blocks_round_trip() {
        let session = SpinSession::local(2).unwrap();
        let eye = session.identity(8, 4).unwrap();
        let blocks: Vec<Block> = eye.block_matrix().unwrap().rdd_clone().into_items();
        let again = session.from_blocks(blocks, 2, 4).unwrap();
        assert_eq!(
            again
                .to_dense()
                .unwrap()
                .max_abs_diff(&Matrix::identity(8)),
            0.0
        );
    }
}
