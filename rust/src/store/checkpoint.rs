//! Mid-job checkpoint/resume for the recursive inversion algorithms.
//!
//! The SPIN and LU schemes recurse over block quadrants; every recursion
//! level ends at a materialization boundary where a whole intermediate
//! [`BlockMatrix`] exists. With `--set checkpoint_every_level=N`, levels
//! at depth `0, N, 2N, …` persist that result to a per-job block store
//! under `<store>/checkpoints/job_<id>/<key>/` and journal a
//! `checkpoint` record in `jobs.log` *after* the blocks are fully on
//! disk — so a record seen at replay implies a complete, loadable
//! checkpoint. A killed server re-enqueues the job with the journaled
//! records attached; when the recursion reaches a checkpointed boundary
//! again it restores the level instead of recomputing it (and its whole
//! subtree). Checkpoint blocks round-trip through [`crate::ser::bin`]
//! bit-exactly, so a resumed job's result is identical to an
//! uninterrupted run's.
//!
//! **Keys are recursion paths**, not sequence numbers: every boundary is
//! named by the child indices from the recursion root (`r`, `r.0`,
//! `r.1.0`, …) plus a part tag for boundaries producing several
//! matrices (`r.0-l` / `r.0-u` for LU's factor pair). Path keys are
//! stable under resume — a restored subtree skips its inner boundaries
//! entirely, which would desync any flat counter, but cannot perturb
//! sibling paths.
//!
//! The context is **thread-local and optional**: the service installs it
//! around a job's execution ([`install`]); everywhere else
//! ([`boundary`] with no context) the algorithms pay one thread-local
//! read per recursion level and nothing more.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::blockmatrix::{Block, BlockMatrix};
use crate::error::Result;
use crate::store::joblog::{CheckpointRecord, JobLog};
use crate::store::{ingest_block_matrix, BlockStore, LocalDirStore};

thread_local! {
    static CTX: RefCell<Option<Ctx>> = const { RefCell::new(None) };
}

struct Ctx {
    job_id: u64,
    /// `<store>/checkpoints/job_<id>` — one subdirectory per key.
    dir: PathBuf,
    /// Persist boundaries whose depth is a multiple of this (0 never
    /// happens: `install` is only called when checkpointing is on).
    every: usize,
    /// Journal for durable `checkpoint` records (`None` in unit tests).
    log: Option<Arc<JobLog>>,
    /// Keys journaled by a previous generation: restorable, and never
    /// re-persisted.
    restorable: BTreeMap<String, (usize, usize)>,
    /// Next-child index per open recursion level (top = current node).
    counters: Vec<usize>,
    /// Child indices from the recursion root to the current node.
    path: Vec<usize>,
}

/// Directory a job's checkpoints live in.
fn job_dir(store_dir: &Path, job_id: u64) -> PathBuf {
    store_dir.join("checkpoints").join(format!("job_{job_id}"))
}

/// Install a checkpoint context on the current thread for the duration
/// of the returned guard (the service wraps one around each job run).
/// `restorable` carries the `checkpoint` records replayed from the job
/// log for this job id. A previously installed context is saved and
/// restored when the guard drops.
pub fn install(
    job_id: u64,
    store_dir: &Path,
    every: usize,
    log: Option<Arc<JobLog>>,
    restorable: &[CheckpointRecord],
) -> InstallGuard {
    let ctx = Ctx {
        job_id,
        dir: job_dir(store_dir, job_id),
        every: every.max(1),
        log,
        restorable: restorable
            .iter()
            .map(|c| (c.key.clone(), (c.nblocks, c.block_size)))
            .collect(),
        counters: Vec::new(),
        path: Vec::new(),
    };
    InstallGuard {
        prev: CTX.with(|c| c.borrow_mut().replace(ctx)),
    }
}

/// RAII guard for [`install`]: dropping it removes the context (and
/// restores whatever was installed before).
pub struct InstallGuard {
    prev: Option<Ctx>,
}

impl Drop for InstallGuard {
    fn drop(&mut self) {
        CTX.with(|c| *c.borrow_mut() = self.prev.take());
    }
}

/// Enter one recursion boundary. Returns `None` when no context is
/// installed — the disabled path costs exactly this thread-local read.
/// The guard names the boundary (path key + depth); dropping it exits
/// the level. Call it for *every* recursion entry, restored or not, so
/// sibling indices stay stable.
pub fn boundary() -> Option<LevelGuard> {
    CTX.with(|c| {
        let mut slot = c.borrow_mut();
        let ctx = slot.as_mut()?;
        let pushed = if let Some(next) = ctx.counters.last_mut() {
            let idx = *next;
            *next += 1;
            ctx.path.push(idx);
            true
        } else {
            false
        };
        ctx.counters.push(0);
        let key_path = if ctx.path.is_empty() {
            "r".to_string()
        } else {
            let segs: Vec<String> = ctx.path.iter().map(|i| i.to_string()).collect();
            format!("r.{}", segs.join("."))
        };
        Some(LevelGuard {
            key_path,
            depth: ctx.path.len(),
            pushed,
        })
    })
}

/// One entered recursion boundary (see [`boundary`]).
pub struct LevelGuard {
    key_path: String,
    depth: usize,
    pushed: bool,
}

impl LevelGuard {
    /// Full checkpoint key for one part of this boundary's result
    /// (`part` is `m` for single-matrix boundaries, `l`/`u` for LU's
    /// factor pair).
    pub fn key(&self, part: &str) -> String {
        format!("{}-{part}", self.key_path)
    }

    /// Recursion depth of this boundary (root = 0).
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Restore this boundary's `part` from a journaled checkpoint.
    /// Returns `None` — falling through to a clean recompute — unless a
    /// replayed record exists for the key, the recorded and on-disk
    /// geometry both match the expectation, and every block reads back.
    pub fn try_restore(&self, part: &str, nblocks: usize, block_size: usize) -> Option<BlockMatrix> {
        let key = self.key(part);
        let dir = CTX.with(|c| {
            let slot = c.borrow();
            let ctx = slot.as_ref()?;
            match ctx.restorable.get(&key) {
                Some(&(nb, bs)) if nb == nblocks && bs == block_size => Some(ctx.dir.join(&key)),
                _ => None,
            }
        })?;
        let (store, meta) = LocalDirStore::open(&dir).ok()?;
        if meta.nblocks != nblocks || meta.block_size != block_size {
            return None;
        }
        let mut blocks = Vec::with_capacity(nblocks * nblocks);
        for bi in 0..nblocks {
            for bj in 0..nblocks {
                blocks.push(Block::new(bi, bj, store.read_block(bi, bj).ok()?));
            }
        }
        BlockMatrix::from_blocks(blocks, nblocks, block_size).ok()
    }

    /// Persist one part of this boundary's computed result, if this
    /// depth is a checkpoint level. Returns `true` only when the blocks
    /// AND the journal record are durably written — the counter the
    /// caller records must mean "resumable". Trivial (single-block)
    /// results and keys already journaled by a prior generation are
    /// skipped. A persist failure is logged and ignored: checkpoints
    /// accelerate recovery, they must never fail the job.
    pub fn persist(&self, part: &str, m: &BlockMatrix) -> bool {
        if m.nblocks() < 2 {
            return false;
        }
        let key = self.key(part);
        let due = CTX.with(|c| {
            let slot = c.borrow();
            let ctx = slot.as_ref()?;
            if self.depth % ctx.every != 0 || ctx.restorable.contains_key(&key) {
                return None;
            }
            Some((ctx.dir.join(&key), ctx.log.clone(), ctx.job_id))
        });
        let Some((dir, log, job_id)) = due else {
            return false;
        };
        let write = || -> Result<()> {
            let store = LocalDirStore::create(&dir, m.nblocks(), m.block_size())?;
            ingest_block_matrix(&store, m)?;
            if let Some(log) = &log {
                log.record_checkpoint(
                    job_id,
                    &CheckpointRecord {
                        key: key.clone(),
                        nblocks: m.nblocks(),
                        block_size: m.block_size(),
                    },
                )?;
            }
            Ok(())
        };
        match write() {
            Ok(()) => true,
            Err(e) => {
                log::warn!("checkpoint `{key}` for job {job_id} failed: {e}");
                false
            }
        }
    }
}

impl Drop for LevelGuard {
    fn drop(&mut self) {
        CTX.with(|c| {
            if let Some(ctx) = c.borrow_mut().as_mut() {
                ctx.counters.pop();
                if self.pushed {
                    ctx.path.pop();
                }
            }
        });
    }
}

/// Remove a job's checkpoint directory — called once the job reaches a
/// durable terminal, after which its checkpoints can never be restored.
pub fn cleanup(store_dir: &Path, job_id: u64) {
    let _ = std::fs::remove_dir_all(job_dir(store_dir, job_id));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::JobConfig;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("spin_ckpt_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn boundary_is_none_without_context() {
        assert!(boundary().is_none());
    }

    #[test]
    fn path_keys_follow_the_recursion_shape() {
        let d = tmpdir("keys");
        let _g = install(1, &d, 1, None, &[]);
        let root = boundary().unwrap();
        assert_eq!(root.key("m"), "r-m");
        assert_eq!(root.depth(), 0);
        {
            let c0 = boundary().unwrap();
            assert_eq!(c0.key("m"), "r.0-m");
            let c00 = boundary().unwrap();
            assert_eq!(c00.key("l"), "r.0.0-l");
            assert_eq!(c00.key("u"), "r.0.0-u");
            assert_eq!(c00.depth(), 2);
        }
        // Sibling after the first subtree fully exited.
        let c1 = boundary().unwrap();
        assert_eq!(c1.key("m"), "r.1-m");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn persist_then_restore_round_trips_bits() {
        let d = tmpdir("roundtrip");
        let mut job = JobConfig::new(16, 4);
        job.seed = 0xC4;
        let m = BlockMatrix::random(&job).unwrap();
        {
            let _g = install(7, &d, 1, None, &[]);
            let lvl = boundary().unwrap();
            assert!(lvl.persist("m", &m));
        }
        let rec = CheckpointRecord {
            key: "r-m".to_string(),
            nblocks: 4,
            block_size: 4,
        };
        let _g = install(7, &d, 1, None, std::slice::from_ref(&rec));
        let lvl = boundary().unwrap();
        let got = lvl.try_restore("m", 4, 4).expect("restorable");
        for bi in 0..4 {
            for bj in 0..4 {
                let want = &m.get_block(bi, bj).unwrap().matrix;
                let have = &got.get_block(bi, bj).unwrap().matrix;
                assert_eq!(have.max_abs_diff(want), 0.0, "block ({bi},{bj})");
            }
        }
        // Geometry mismatches and unknown keys fall through to compute.
        assert!(lvl.try_restore("m", 2, 4).is_none());
        assert!(lvl.try_restore("x", 4, 4).is_none());
        // A restored key is never re-persisted (already durable).
        assert!(!lvl.persist("m", &m));
        drop(lvl);
        drop(_g);
        cleanup(&d, 7);
        assert!(!d.join("checkpoints").join("job_7").exists());
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn every_gates_depths_and_leaves_are_skipped() {
        let d = tmpdir("every");
        let mut job = JobConfig::new(8, 4);
        job.seed = 1;
        let m = BlockMatrix::random(&job).unwrap(); // 2x2 grid
        let single = BlockMatrix::identity(4, 4).unwrap(); // 1x1 grid
        let _g = install(9, &d, 2, None, &[]);
        let root = boundary().unwrap(); // depth 0: due
        assert!(root.persist("m", &m));
        assert!(!root.persist("m", &single), "single-block results skipped");
        let child = boundary().unwrap(); // depth 1: off-cycle
        assert!(!child.persist("m", &m));
        let grand = boundary().unwrap(); // depth 2: due
        assert!(grand.persist("m", &m));
        let _ = std::fs::remove_dir_all(&d);
    }
}
