//! Durable job log: append-only JSONL under the store directory that
//! makes a `spin serve --http` server crash-restartable.
//!
//! Every accepted submit appends a `submitted` record (job id + full
//! [`JobSpec`]) and every terminal phase flip appends a `terminal`
//! record, each fsynced before the state becomes externally visible —
//! so a job a client saw acknowledged is never lost, and a job a client
//! saw finish never re-executes. On startup the server replays the log:
//! ids with a `submitted` but no `terminal` record were queued or
//! running at crash time and are re-enqueued under their original ids
//! (resubmit over HTTP is idempotent by id); ids with a `terminal`
//! record are served from the log without re-execution.
//!
//! Each server start appends a `generation` header record carrying the
//! format tag and a monotonically increasing generation number, so the
//! log itself records every restart boundary.

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::error::{Result, SpinError};
use crate::ser::json::Json;
use crate::service::{JobSpec, JobStatus};
use crate::util::{now_ms, plock};

/// Log file name inside the store directory.
pub const JOB_LOG_FILE: &str = "jobs.log";

/// Format tag written in every generation header.
pub const JOB_LOG_FORMAT: &str = "spin-joblog-v1";

/// Append-only writer for the durable job log. One per server process;
/// appends are serialized by an internal lock and fsynced before
/// returning, so a record that `record_*` acknowledged survives a crash.
pub struct JobLog {
    file: Mutex<File>,
    path: PathBuf,
    generation: u64,
}

/// Terminal outcome as recorded in the log (no dense result payload —
/// results are recomputable from the spec; the log is for control state).
#[derive(Debug, Clone, PartialEq)]
pub struct Terminal {
    pub status: JobStatus,
    pub error: Option<String>,
    pub residual: Option<f64>,
}

/// One durably journaled mid-job checkpoint: a recursion-level result
/// the job persisted to the block store before it (maybe) crashed. The
/// record is appended *after* the blocks are fully written, so replaying
/// one guarantees the on-disk checkpoint is complete.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CheckpointRecord {
    /// Recursion-path key (e.g. `r.0.1-m`), unique within the job.
    pub key: String,
    /// Block grid of the checkpointed matrix.
    pub nblocks: usize,
    /// Block size of the checkpointed matrix.
    pub block_size: usize,
}

/// One job reconstructed from the log: its spec plus, if it finished,
/// the terminal record. `terminal: None` means the job was queued or
/// running at crash time and must be re-enqueued.
#[derive(Debug, Clone)]
pub struct ReplayedJob {
    pub id: u64,
    pub spec: JobSpec,
    pub terminal: Option<Terminal>,
    /// Checkpoints journaled before the crash — a re-enqueued job restores
    /// these levels from the block store instead of recomputing them.
    pub checkpoints: Vec<CheckpointRecord>,
}

/// Everything recovered from an existing log at startup.
#[derive(Debug, Default)]
pub struct JobLogReplay {
    /// Highest generation header seen (0 when the log is new/empty).
    pub generation: u64,
    /// Jobs in id order, deduplicated (first `submitted` record wins).
    pub jobs: Vec<ReplayedJob>,
}

impl JobLogReplay {
    /// Jobs that never reached a terminal phase — the restart re-enqueues
    /// exactly these.
    pub fn pending(&self) -> impl Iterator<Item = &ReplayedJob> {
        self.jobs.iter().filter(|j| j.terminal.is_none())
    }

    /// Largest job id seen; the restarted server allocates above this.
    pub fn max_id(&self) -> u64 {
        self.jobs.iter().map(|j| j.id).max().unwrap_or(0)
    }
}

impl JobLog {
    /// Open (creating if absent) the job log in `dir`, replaying any
    /// existing records first. Returns the writer — positioned at a new
    /// generation, header already appended and fsynced — plus the replay.
    pub fn open(dir: impl Into<PathBuf>) -> Result<(JobLog, JobLogReplay)> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(JOB_LOG_FILE);
        let replay = if path.exists() {
            replay_file(&path)?
        } else {
            JobLogReplay::default()
        };
        let file = OpenOptions::new().create(true).append(true).open(&path)?;
        let log = JobLog {
            file: Mutex::new(file),
            path,
            generation: replay.generation + 1,
        };
        log.append(Json::object(vec![
            ("type", Json::str("generation")),
            ("format", Json::str(JOB_LOG_FORMAT)),
            ("generation", Json::num(log.generation as f64)),
            ("ts_ms", Json::num(now_ms() as f64)),
        ]))?;
        Ok((log, replay))
    }

    /// Generation number of this writer (1 for a fresh log).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Store directory the log lives in — checkpoint data is kept under
    /// `<dir>/checkpoints/`.
    pub fn dir(&self) -> &Path {
        self.path.parent().unwrap_or_else(|| Path::new("."))
    }

    /// Record an accepted submit. Must be called (and return) before the
    /// job id is acknowledged to the client.
    pub fn record_submitted(&self, id: u64, spec: &JobSpec) -> Result<()> {
        self.append(Json::object(vec![
            ("type", Json::str("submitted")),
            ("id", Json::num(id as f64)),
            ("spec", spec.to_json()),
            ("ts_ms", Json::num(now_ms() as f64)),
        ]))
    }

    /// Record a terminal phase. Must be called (and return) before the
    /// phase flip is published, so a crash after a client observed
    /// completion cannot re-execute the job.
    pub fn record_terminal(
        &self,
        id: u64,
        status: JobStatus,
        error: Option<&str>,
        residual: Option<f64>,
    ) -> Result<()> {
        let mut pairs = vec![
            ("type", Json::str("terminal")),
            ("id", Json::num(id as f64)),
            ("status", Json::str(status.name())),
            ("ts_ms", Json::num(now_ms() as f64)),
        ];
        if let Some(e) = error {
            pairs.push(("error", Json::str(e)));
        }
        if let Some(r) = residual {
            pairs.push(("residual", Json::Number(r)));
        }
        self.append(Json::object(pairs))
    }

    /// Record a completed mid-job checkpoint. Must be called only after
    /// the checkpoint's blocks are fully on disk: the record is the
    /// durability point replay trusts.
    pub fn record_checkpoint(&self, id: u64, ckpt: &CheckpointRecord) -> Result<()> {
        self.append(Json::object(vec![
            ("type", Json::str("checkpoint")),
            ("id", Json::num(id as f64)),
            ("key", Json::str(ckpt.key.as_str())),
            ("nblocks", Json::num(ckpt.nblocks as f64)),
            ("block_size", Json::num(ckpt.block_size as f64)),
            ("ts_ms", Json::num(now_ms() as f64)),
        ]))
    }

    /// One fsynced line: write + `sync_data` under the writer lock, so
    /// concurrent workers' records never interleave and an acknowledged
    /// record is on disk.
    fn append(&self, record: Json) -> Result<()> {
        let mut line = record.compact();
        line.push('\n');
        let file = plock(&self.file);
        (&*file).write_all(line.as_bytes())?;
        file.sync_data()?;
        Ok(())
    }
}

/// Parse an existing log. A torn final line (crash mid-append) is
/// tolerated and skipped; any earlier malformed record is an error —
/// that is corruption, not a crash artifact.
fn replay_file(path: &Path) -> Result<JobLogReplay> {
    let text = std::fs::read_to_string(path)?;
    let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
    let mut generation = 0u64;
    let mut jobs: BTreeMap<u64, ReplayedJob> = BTreeMap::new();
    for (idx, line) in lines.iter().enumerate() {
        let last = idx + 1 == lines.len();
        let record = match Json::parse(line) {
            Ok(v) => v,
            Err(_) if last => break, // torn tail from a crash mid-append
            Err(e) => {
                return Err(SpinError::config(format!(
                    "corrupt job log {} at record {}: {e}",
                    path.display(),
                    idx + 1
                )));
            }
        };
        let parsed = parse_record(&record, &mut generation, &mut jobs);
        if let Err(e) = parsed {
            if last {
                break;
            }
            return Err(SpinError::config(format!(
                "corrupt job log {} at record {}: {e}",
                path.display(),
                idx + 1
            )));
        }
    }
    Ok(JobLogReplay {
        generation,
        jobs: jobs.into_values().collect(),
    })
}

fn parse_record(
    record: &Json,
    generation: &mut u64,
    jobs: &mut BTreeMap<u64, ReplayedJob>,
) -> Result<()> {
    let kind = record
        .req("type")?
        .as_str()
        .ok_or_else(|| SpinError::config("record `type` must be a string"))?;
    match kind {
        "generation" => {
            let format = record
                .req("format")?
                .as_str()
                .ok_or_else(|| SpinError::config("generation `format` must be a string"))?;
            if format != JOB_LOG_FORMAT {
                return Err(SpinError::config(format!(
                    "unsupported job log format `{format}` (expected `{JOB_LOG_FORMAT}`)"
                )));
            }
            let g = record
                .req("generation")?
                .as_i64()
                .and_then(|v| u64::try_from(v).ok())
                .ok_or_else(|| SpinError::config("generation number must be a u64"))?;
            *generation = (*generation).max(g);
        }
        "submitted" => {
            let id = record_id(record)?;
            let spec = JobSpec::from_json(record.req("spec")?)?;
            // Dedup by id: a restarted generation re-logs its re-enqueues,
            // so later submitted records for a known id are echoes.
            jobs.entry(id).or_insert(ReplayedJob {
                id,
                spec,
                terminal: None,
                checkpoints: Vec::new(),
            });
        }
        "checkpoint" => {
            let id = record_id(record)?;
            let key = record
                .req("key")?
                .as_str()
                .ok_or_else(|| SpinError::config("checkpoint `key` must be a string"))?
                .to_string();
            let nblocks = record_usize(record, "nblocks")?;
            let block_size = record_usize(record, "block_size")?;
            // A checkpoint for an unknown id means the log was truncated
            // externally; like orphan terminals, skip it.
            if let Some(job) = jobs.get_mut(&id) {
                let ckpt = CheckpointRecord {
                    key,
                    nblocks,
                    block_size,
                };
                // Re-run generations may re-journal a level; keep one.
                if !job.checkpoints.iter().any(|c| c.key == ckpt.key) {
                    job.checkpoints.push(ckpt);
                }
            }
        }
        "terminal" => {
            let id = record_id(record)?;
            let status = JobStatus::parse(
                record
                    .req("status")?
                    .as_str()
                    .ok_or_else(|| SpinError::config("terminal `status` must be a string"))?,
            )?;
            let terminal = Terminal {
                status,
                error: record.get("error").and_then(|v| v.as_str()).map(String::from),
                residual: record.get("residual").and_then(|v| v.as_f64()),
            };
            // Terminal without a submitted record can only happen if the
            // log was truncated externally; nothing to resume, skip it.
            if let Some(job) = jobs.get_mut(&id) {
                job.terminal.get_or_insert(terminal);
            }
        }
        other => {
            return Err(SpinError::config(format!(
                "unknown job log record type `{other}`"
            )));
        }
    }
    Ok(())
}

fn record_usize(record: &Json, field: &str) -> Result<usize> {
    record
        .req(field)?
        .as_i64()
        .and_then(|v| usize::try_from(v).ok())
        .filter(|&v| v > 0)
        .ok_or_else(|| SpinError::config(format!("record `{field}` must be a positive integer")))
}

fn record_id(record: &Json) -> Result<u64> {
    record
        .req("id")?
        .as_i64()
        .and_then(|v| u64::try_from(v).ok())
        .filter(|&id| id > 0)
        .ok_or_else(|| SpinError::config("record `id` must be a positive integer"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::MatrixSpec;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("spin_joblog_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn spec(seed: u64) -> JobSpec {
        JobSpec::invert(MatrixSpec::new(16, 4).seeded(seed)).label("t")
    }

    #[test]
    fn log_replays_pending_and_terminal_jobs() {
        let d = tmpdir("replay");
        let (log, replay) = JobLog::open(&d).unwrap();
        assert_eq!(log.generation(), 1);
        assert_eq!(replay.generation, 0);
        assert!(replay.jobs.is_empty());
        log.record_submitted(1, &spec(1)).unwrap();
        log.record_submitted(2, &spec(2)).unwrap();
        log.record_submitted(3, &spec(3)).unwrap();
        log.record_terminal(1, JobStatus::Completed, None, Some(1e-12))
            .unwrap();
        log.record_terminal(3, JobStatus::Failed, Some("boom"), None)
            .unwrap();
        drop(log);

        let (log2, replay) = JobLog::open(&d).unwrap();
        assert_eq!(log2.generation(), 2);
        assert_eq!(replay.generation, 1);
        assert_eq!(replay.jobs.len(), 3);
        assert_eq!(replay.max_id(), 3);
        let pending: Vec<u64> = replay.pending().map(|j| j.id).collect();
        assert_eq!(pending, vec![2], "only the unterminated job is pending");
        let done = &replay.jobs[0];
        let t = done.terminal.as_ref().unwrap();
        assert_eq!(t.status, JobStatus::Completed);
        assert_eq!(t.residual, Some(1e-12));
        let failed = replay.jobs[2].terminal.as_ref().unwrap();
        assert_eq!(failed.status, JobStatus::Failed);
        assert_eq!(failed.error.as_deref(), Some("boom"));
        assert_eq!(replay.jobs[1].spec, spec(2));
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn resubmitted_ids_dedup_across_generations() {
        let d = tmpdir("dedup");
        let (log, _) = JobLog::open(&d).unwrap();
        log.record_submitted(5, &spec(5)).unwrap();
        drop(log);
        // Restarted generation re-logs the re-enqueue of id 5, then
        // finishes it.
        let (log, replay) = JobLog::open(&d).unwrap();
        assert_eq!(replay.pending().count(), 1);
        log.record_submitted(5, &spec(5)).unwrap();
        log.record_terminal(5, JobStatus::Completed, None, None).unwrap();
        drop(log);
        let (_, replay) = JobLog::open(&d).unwrap();
        assert_eq!(replay.jobs.len(), 1, "one job despite two submitted records");
        assert!(replay.pending().next().is_none());
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn torn_tail_is_tolerated_but_midfile_corruption_errors() {
        let d = tmpdir("torn");
        let (log, _) = JobLog::open(&d).unwrap();
        log.record_submitted(1, &spec(1)).unwrap();
        let path = log.path().to_path_buf();
        drop(log);
        // Simulate a crash mid-append: partial JSON on the final line.
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("{\"type\":\"termi");
        std::fs::write(&path, &text).unwrap();
        let (_, replay) = JobLog::open(&d).unwrap();
        assert_eq!(replay.pending().count(), 1, "torn tail skipped");
        // Corruption before the tail is a hard error.
        let mut lines: Vec<String> =
            std::fs::read_to_string(&path).unwrap().lines().map(String::from).collect();
        lines.insert(1, "not json".to_string());
        std::fs::write(&path, lines.join("\n")).unwrap();
        assert!(JobLog::open(&d).is_err());
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn checkpoint_records_replay_with_pending_jobs_and_dedup() {
        let d = tmpdir("ckpt");
        let (log, _) = JobLog::open(&d).unwrap();
        assert_eq!(log.dir(), d.as_path());
        log.record_submitted(7, &spec(7)).unwrap();
        let ck = |key: &str| CheckpointRecord {
            key: key.to_string(),
            nblocks: 4,
            block_size: 16,
        };
        log.record_checkpoint(7, &ck("r-m")).unwrap();
        log.record_checkpoint(7, &ck("r.0-m")).unwrap();
        // Orphan checkpoint (no submitted record) is skipped, not fatal.
        log.record_checkpoint(99, &ck("r-m")).unwrap();
        drop(log);
        let (log, replay) = JobLog::open(&d).unwrap();
        let job = replay.jobs.iter().find(|j| j.id == 7).unwrap();
        assert!(job.terminal.is_none(), "still pending");
        assert_eq!(job.checkpoints, vec![ck("r-m"), ck("r.0-m")]);
        assert!(!replay.jobs.iter().any(|j| j.id == 99));
        // A resumed generation re-journals the same key: deduped.
        log.record_submitted(7, &spec(7)).unwrap();
        log.record_checkpoint(7, &ck("r-m")).unwrap();
        log.record_terminal(7, JobStatus::Completed, None, Some(1e-12))
            .unwrap();
        drop(log);
        let (_, replay) = JobLog::open(&d).unwrap();
        let job = replay.jobs.iter().find(|j| j.id == 7).unwrap();
        assert_eq!(job.checkpoints.len(), 2, "re-journaled key deduped");
        assert!(job.terminal.is_some());
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn generation_header_carries_format_tag() {
        let d = tmpdir("gen");
        let (log, _) = JobLog::open(&d).unwrap();
        let first = std::fs::read_to_string(log.path())
            .unwrap()
            .lines()
            .next()
            .unwrap()
            .to_string();
        let header = Json::parse(&first).unwrap();
        assert_eq!(header.get("type").unwrap().as_str(), Some("generation"));
        assert_eq!(header.get("format").unwrap().as_str(), Some(JOB_LOG_FORMAT));
        assert_eq!(header.get("generation").unwrap().as_i64(), Some(1));
        assert!(header.get("ts_ms").unwrap().as_i64().unwrap() > 0);
        let _ = std::fs::remove_dir_all(&d);
    }
}
