//! Block stores: the HDFS-style ingest/load path for distributed
//! matrices.
//!
//! A store holds one matrix as a directory of files — `meta.json` with
//! the grid shape plus one [`crate::ser::bin`] dense file per `(i, j)`
//! block — so the unit of I/O is the unit of distribution. The serving
//! stack consumes stores through **lazy plan leaves**: a
//! [`crate::service::MatrixSpec::from_store`] (or
//! [`crate::session::SpinSession::from_store`]) handle returns after
//! reading only `meta.json`; block files are read per-partition on the
//! workers at first materialization, never driver-side at submit.
//!
//! [`BlockStore`] is the pluggable interface (a future HDFS/S3 client
//! implements it); [`LocalDirStore`] is the local-filesystem
//! implementation behind `spin ingest` and `spin serve --store`.
//!
//! The store directory also hosts the serving stack's durability state:
//! [`joblog`] is the append-only job log that lets `spin serve --http`
//! resume queued/running jobs after a crash.

pub mod checkpoint;
pub mod joblog;

pub use joblog::{CheckpointRecord, JobLog, JobLogReplay, ReplayedJob, Terminal};

use std::path::{Path, PathBuf};

use crate::blockmatrix::BlockMatrix;
use crate::config::JobConfig;
use crate::error::{Result, SpinError};
use crate::linalg::{self, Matrix};
use crate::ser::bin;

pub use crate::ser::bin::BlockStoreMeta;

/// One stored distributed matrix: square `nblocks × nblocks` grid of
/// square `block_size` blocks, addressable per block. Implementations
/// must be safe to read from concurrent worker tasks.
pub trait BlockStore: Send + Sync {
    /// Grid shape of the stored matrix.
    fn meta(&self) -> Result<BlockStoreMeta>;

    /// Read one block's payload.
    fn read_block(&self, bi: usize, bj: usize) -> Result<Matrix>;

    /// Write one block's payload (ingest path).
    fn write_block(&self, bi: usize, bj: usize, m: &Matrix) -> Result<()>;
}

/// [`BlockStore`] over a local directory in the `ser::bin` layout:
/// `meta.json` + `block_<i>_<j>.mat`, one serialized block per file.
pub struct LocalDirStore {
    dir: PathBuf,
}

impl LocalDirStore {
    /// Open an existing store (validates `meta.json`).
    pub fn open(dir: impl Into<PathBuf>) -> Result<(Self, BlockStoreMeta)> {
        let store = LocalDirStore { dir: dir.into() };
        let meta = store.meta()?;
        Ok((store, meta))
    }

    /// Create (or overwrite) a store directory for the given grid shape.
    /// Overwriting first removes every `block_*.mat` file left by a
    /// previous store — block files carry no identity tying them to
    /// `meta.json`, so stale leftovers from an older (larger, or
    /// differently seeded) store would otherwise be served silently.
    pub fn create(dir: impl Into<PathBuf>, nblocks: usize, block_size: usize) -> Result<Self> {
        let dir: PathBuf = dir.into();
        if nblocks == 0 || block_size == 0 {
            return Err(SpinError::config(
                "block store needs a positive grid and block size",
            ));
        }
        std::fs::create_dir_all(&dir)?;
        for entry in std::fs::read_dir(&dir)? {
            let path = entry?.path();
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if path.is_file() && name.starts_with("block_") && name.ends_with(".mat") {
                std::fs::remove_file(&path)?;
            }
        }
        bin::write_block_store(&dir, nblocks, block_size, std::iter::empty())?;
        Ok(LocalDirStore { dir })
    }

    /// Wrap a directory without touching the filesystem — the lazy-leaf
    /// path, where `meta.json` was already validated at spec time and
    /// block reads happen on the workers.
    pub fn open_unchecked(dir: impl Into<PathBuf>) -> Self {
        LocalDirStore { dir: dir.into() }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

impl BlockStore for LocalDirStore {
    fn meta(&self) -> Result<BlockStoreMeta> {
        bin::read_block_store_meta(&self.dir)
    }

    fn read_block(&self, bi: usize, bj: usize) -> Result<Matrix> {
        bin::read_block(&self.dir, bi, bj)
    }

    fn write_block(&self, bi: usize, bj: usize, m: &Matrix) -> Result<()> {
        bin::write_matrix(&self.dir.join(format!("block_{bi}_{bj}.mat")), m)
    }
}

/// Ingest a generated matrix into a store **block by block**: per-block
/// RNG streams mean the driver holds one block at a time, so ingest is
/// O(block) memory at any matrix size. The stored bits equal what the
/// eager and lazy generation paths produce for the same job parameters.
pub fn ingest_generated(store: &dyn BlockStore, job: &JobConfig) -> Result<usize> {
    job.validate()?;
    let nblocks = job.num_splits();
    for bi in 0..nblocks {
        for bj in 0..nblocks {
            let block =
                linalg::generate_block(job.generator, job.n, job.block_size, bi, bj, job.seed);
            store.write_block(bi, bj, &block)?;
        }
    }
    Ok(nblocks * nblocks)
}

/// Write an already-materialized distributed matrix into a store.
pub fn ingest_block_matrix(store: &dyn BlockStore, m: &BlockMatrix) -> Result<usize> {
    let meta = store.meta()?;
    if meta.nblocks != m.nblocks() || meta.block_size != m.block_size() {
        return Err(SpinError::shape(format!(
            "store grid {}x{} of {} does not match matrix grid {}x{} of {}",
            meta.nblocks,
            meta.nblocks,
            meta.block_size,
            m.nblocks(),
            m.nblocks(),
            m.block_size()
        )));
    }
    let mut written = 0usize;
    for bi in 0..m.nblocks() {
        for bj in 0..m.nblocks() {
            let block = m
                .get_block(bi, bj)
                .ok_or_else(|| SpinError::shape(format!("grid missing block ({bi},{bj})")))?;
            store.write_block(bi, bj, &block.matrix)?;
            written += 1;
        }
    }
    Ok(written)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GeneratorKind;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("spin_store_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn ingest_generated_round_trips_against_eager_random() {
        let d = tmpdir("gen");
        let mut job = JobConfig::new(32, 8);
        job.seed = 11;
        job.generator = GeneratorKind::Spd;
        let store = LocalDirStore::create(&d, job.num_splits(), job.block_size).unwrap();
        assert_eq!(ingest_generated(&store, &job).unwrap(), 16);
        let (reopened, meta) = LocalDirStore::open(&d).unwrap();
        assert_eq!((meta.nblocks, meta.block_size), (4, 8));
        let eager = BlockMatrix::random(&job).unwrap();
        for bi in 0..4 {
            for bj in 0..4 {
                let stored = reopened.read_block(bi, bj).unwrap();
                let want = &eager.get_block(bi, bj).unwrap().matrix;
                assert_eq!(stored.max_abs_diff(want), 0.0, "block ({bi},{bj})");
            }
        }
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn ingest_block_matrix_validates_grid() {
        let d = tmpdir("bm");
        let store = LocalDirStore::create(&d, 2, 4).unwrap();
        let m = BlockMatrix::identity(8, 4).unwrap();
        assert_eq!(ingest_block_matrix(&store, &m).unwrap(), 4);
        let wrong = BlockMatrix::identity(8, 2).unwrap();
        assert!(ingest_block_matrix(&store, &wrong).is_err());
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn create_clears_stale_blocks_from_a_previous_store() {
        let d = tmpdir("stale");
        let big = LocalDirStore::create(&d, 4, 4).unwrap();
        ingest_generated(&big, &JobConfig::new(16, 4)).unwrap();
        assert!(d.join("block_3_3.mat").exists());
        // Re-create the same directory as a SMALLER store: the old
        // out-of-grid block files must not survive to be served later.
        let small = LocalDirStore::create(&d, 2, 4).unwrap();
        ingest_generated(&small, &JobConfig::new(8, 4)).unwrap();
        assert!(!d.join("block_3_3.mat").exists(), "stale block cleared");
        let (_, meta) = LocalDirStore::open(&d).unwrap();
        assert_eq!((meta.nblocks, meta.block_size), (2, 4));
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn open_rejects_missing_and_create_rejects_degenerate() {
        assert!(LocalDirStore::open("/definitely/missing/store").is_err());
        assert!(LocalDirStore::create(tmpdir("bad"), 0, 4).is_err());
    }
}
