//! Mini property-testing harness (`proptest` is not in the offline vendor
//! set).  Runs a property over N randomly generated cases from an explicit
//! base seed; on failure, reports the exact per-case seed so the
//! counterexample is one `case_seed` away from reproduction.

use crate::util::rng::Rng;

/// Number of cases for a default property run.
pub const DEFAULT_CASES: usize = 32;

/// Run `prop` over `cases` inputs drawn by `gen` from a seeded RNG.
///
/// `gen` receives a fresh, deterministic RNG per case. `prop` returns
/// `Err(description)` to fail. Panics with the case index, seed and
/// description on the first failure.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    base_seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> std::result::Result<(), String>,
) {
    for case in 0..cases {
        let case_seed = base_seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case as u64);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property `{name}` failed at case {case}/{cases} \
                 (case_seed={case_seed:#x}): {msg}\ninput: {input:?}"
            );
        }
    }
}

/// Assert two f64s agree to a relative-or-absolute tolerance.
pub fn close(a: f64, b: f64, rtol: f64, atol: f64) -> std::result::Result<(), String> {
    let diff = (a - b).abs();
    let bound = atol + rtol * b.abs().max(a.abs());
    if diff <= bound || (a.is_nan() && b.is_nan()) {
        Ok(())
    } else {
        Err(format!("{a} vs {b} differ by {diff} > {bound}"))
    }
}

/// Max elementwise |a-b| over two slices (∞-norm distance).
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivially() {
        forall(
            "square non-negative",
            1,
            DEFAULT_CASES,
            |r| r.uniform(-10.0, 10.0),
            |x| {
                if x * x >= 0.0 {
                    Ok(())
                } else {
                    Err("negative square".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property `always-fails`")]
    fn forall_reports_failure_with_seed() {
        forall(
            "always-fails",
            2,
            4,
            |r| r.next_u64(),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0 + 1e-12, 1e-9, 0.0).is_ok());
        assert!(close(1.0, 1.1, 1e-9, 0.0).is_err());
        assert!(close(0.0, 1e-12, 0.0, 1e-9).is_ok());
    }

    #[test]
    fn max_abs_diff_works() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.0, 4.5]), 2.5);
    }
}
