//! Human-readable formatting for the report/bench output.

/// `1536 -> "1.5 KiB"`.
pub fn bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut i = 0;
    while v >= 1024.0 && i < UNITS.len() - 1 {
        v /= 1024.0;
        i += 1;
    }
    if i == 0 {
        format!("{n} B")
    } else {
        format!("{v:.1} {}", UNITS[i])
    }
}

/// Seconds to an adaptive "ms"/"s" string.
pub fn secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.1} ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2} s")
    } else {
        format!("{:.1} min", s / 60.0)
    }
}

/// GFLOP/s from flop count and seconds.
pub fn gflops(flops: f64, s: f64) -> String {
    format!("{:.2} GF/s", flops / s / 1e9)
}

/// Fixed-width ASCII table writer used by all experiment reports.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "table arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut line = String::from("|");
            for (c, cell) in cells.iter().enumerate() {
                line.push_str(&format!(" {:>w$} |", cell, w = widths[c]));
            }
            line
        };
        let sep = {
            let mut line = String::from("|");
            for w in &widths {
                line.push_str(&format!("{}|", "-".repeat(w + 2)));
            }
            line
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Comma-separated form for `bench_results/*.csv`.
    pub fn to_csv(&self) -> String {
        let mut out = self.header.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_units() {
        assert_eq!(bytes(12), "12 B");
        assert_eq!(bytes(2048), "2.0 KiB");
        assert_eq!(bytes(3 * 1024 * 1024), "3.0 MiB");
    }

    #[test]
    fn secs_units() {
        assert_eq!(secs(5e-7), "0.5 µs");
        assert_eq!(secs(0.25), "250.0 ms");
        assert_eq!(secs(2.5), "2.50 s");
        assert_eq!(secs(300.0), "5.0 min");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(vec!["n", "time"]);
        t.row(vec!["64", "1.0"]);
        t.row(vec!["16384", "200.5"]);
        let s = t.render();
        assert!(s.contains("| 16384 |"));
        assert!(s.lines().count() == 4);
        assert!(t.to_csv().starts_with("n,time\n64,1.0\n"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_arity_checked() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }
}
