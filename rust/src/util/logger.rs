//! Minimal stderr logger backing the `log` facade (`env_logger` is not in
//! the offline vendor set).  Level comes from `SPIN_LOG` (error|warn|info|
//! debug|trace), default `info`.

use std::io::Write;

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger;

static LOGGER: StderrLogger = StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let tag = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        let mut err = std::io::stderr().lock();
        let _ = writeln!(err, "[{tag}] {}: {}", record.target(), record.args());
    }

    fn flush(&self) {
        let _ = std::io::stderr().flush();
    }
}

/// Install the logger once; safe to call repeatedly.
pub fn init() {
    let level = match std::env::var("SPIN_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        Ok("off") => LevelFilter::Off,
        _ => LevelFilter::Info,
    };
    if log::set_logger(&LOGGER).is_ok() {
        log::set_max_level(level);
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke");
    }
}
