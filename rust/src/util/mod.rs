//! Small shared utilities: deterministic RNG, timing, logging, a
//! mini property-testing harness, and human-readable formatting.
//!
//! These exist because the offline vendor set has no `rand`, `env_logger`,
//! `criterion` or `proptest`; each module is a purpose-built replacement
//! scoped to what this crate needs.

pub mod check;
pub mod fmt;
pub mod logger;
pub mod rng;
pub mod timer;

pub use rng::Rng;
pub use timer::Stopwatch;
