//! Small shared utilities: deterministic RNG, timing, logging, a
//! mini property-testing harness, and human-readable formatting.
//!
//! These exist because the offline vendor set has no `rand`, `env_logger`,
//! `criterion` or `proptest`; each module is a purpose-built replacement
//! scoped to what this crate needs.

pub mod check;
pub mod fmt;
pub mod logger;
pub mod rng;
pub mod timer;

pub use rng::Rng;
pub use timer::Stopwatch;

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};

/// Poison-tolerant mutex lock. A panicking job (worker task, user
/// algorithm, generator) must fail *that job*, not wedge every later
/// caller of the lock it happened to hold — the guarded states in this
/// crate are all written atomically-enough that recovering the guard is
/// safe (memo slots hold `Option`s set in one assignment, queues/maps are
/// structurally consistent between method calls).
pub fn plock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Poison-tolerant condvar wait — companion to [`plock`].
pub fn pwait<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(PoisonError::into_inner)
}

/// Wall-clock milliseconds since the Unix epoch — the timestamp format
/// used by job-log records and SSE phase events.
pub fn now_ms() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}
