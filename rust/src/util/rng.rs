//! Deterministic, seedable PRNG (xoshiro256**, seeded via SplitMix64).
//!
//! Replaces the paper's `java.util.Random` test-matrix generator.  All
//! experiment workloads derive from explicit seeds so every figure is
//! exactly re-generable.

/// xoshiro256** by Blackman & Vigna — fast, high-quality, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed the generator; any u64 is valid (SplitMix64 expands it).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next_sm(), next_sm(), next_sm(), next_sm()],
        }
    }

    /// Derive an independent stream (for per-block / per-partition seeding).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our use).
    #[inline]
    pub fn next_usize(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fill a slice with uniform [lo, hi) samples.
    pub fn fill_uniform(&mut self, buf: &mut [f64], lo: f64, hi: f64) {
        for v in buf.iter_mut() {
            *v = self.uniform(lo, hi);
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_usize(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_respects_bounds_and_mean() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.uniform(-2.0, 6.0);
            assert!((-2.0..6.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(5);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn next_usize_bounds() {
        let mut r = Rng::new(17);
        for _ in 0..1000 {
            assert!(r.next_usize(7) < 7);
        }
    }
}
