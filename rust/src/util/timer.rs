//! Wall-clock timing helpers for the metrics registry and bench harness.

use std::time::{Duration, Instant};

/// A restartable stopwatch accumulating elapsed time across segments.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    accumulated: Duration,
    started: Option<Instant>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch {
            accumulated: Duration::ZERO,
            started: None,
        }
    }

    /// Create and immediately start.
    pub fn started() -> Self {
        let mut s = Self::new();
        s.start();
        s
    }

    pub fn start(&mut self) {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
    }

    pub fn stop(&mut self) {
        if let Some(t0) = self.started.take() {
            self.accumulated += t0.elapsed();
        }
    }

    pub fn reset(&mut self) {
        self.accumulated = Duration::ZERO;
        self.started = None;
    }

    /// Total accumulated time, including a running segment.
    pub fn elapsed(&self) -> Duration {
        match self.started {
            Some(t0) => self.accumulated + t0.elapsed(),
            None => self.accumulated,
        }
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }
}

/// Time a closure, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Repeat a closure `reps` times and return the minimum seconds (bench idiom:
/// min is the least noisy estimator of the true cost on a shared box).
pub fn min_time_of<T>(reps: usize, mut f: impl FnMut() -> T) -> f64 {
    assert!(reps > 0);
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        let out = f();
        std::hint::black_box(&out);
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        sw.start();
        std::thread::sleep(Duration::from_millis(5));
        sw.stop();
        let first = sw.elapsed();
        assert!(first >= Duration::from_millis(4));
        sw.start();
        std::thread::sleep(Duration::from_millis(5));
        sw.stop();
        assert!(sw.elapsed() > first);
    }

    #[test]
    fn stopwatch_reset() {
        let mut sw = Stopwatch::started();
        std::thread::sleep(Duration::from_millis(2));
        sw.reset();
        assert_eq!(sw.elapsed(), Duration::ZERO);
    }

    #[test]
    fn double_start_is_idempotent() {
        let mut sw = Stopwatch::new();
        sw.start();
        sw.start();
        sw.stop();
        sw.stop();
        assert!(sw.elapsed() < Duration::from_secs(1));
    }

    #[test]
    fn time_it_returns_value() {
        let (v, secs) = time_it(|| 40 + 2);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn min_time_positive() {
        let t = min_time_of(3, || (0..1000).sum::<u64>());
        assert!(t > 0.0);
    }
}
