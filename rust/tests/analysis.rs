//! Acceptance tests for the static plan verifier (`spin::analysis`).
//!
//! The analyzer's derived cost profiles are **contracts**, not estimates:
//! exchange-stage and collect counts are equalities (ceilings for
//! iterative schemes), shuffle bytes are proved upper bounds. Every test
//! here holds a prediction made *before* execution against what a real
//! run measured — across block sizes, executor widths, and deterministic
//! fault injection, with the `verify_plans` per-node runtime cross-check
//! armed the whole time.

use spin::config::ClusterConfig;
use spin::service::{JobSpec, MatrixSpec, SpinService};
use spin::session::SpinSession;

const N: usize = 128;

/// A 4-slot local cluster with chaos on (panics, task errors,
/// stragglers), a generous retry budget, and the `verify_plans` debug
/// mode armed: every executed plan node fails its job if its measured
/// stages/bytes/collects diverge from the static prediction.
fn chaos_config(exec_threads: usize, fault_seed: u64) -> ClusterConfig {
    let mut cfg = ClusterConfig::local(4);
    cfg.exec_threads = exec_threads;
    cfg.verify_plans = true;
    cfg.fault_seed = Some(fault_seed);
    cfg.fault_rate = 0.1;
    cfg.task_retries = 5;
    cfg
}

/// The tentpole property: analyzer-predicted stage counts equal measured
/// `shuffle_stages` for every built-in scheme at n=128, bs ∈ {16, 32},
/// exec_threads ∈ {1, 4}, under fault-injection chaos — retries and
/// speculation re-run tasks, never stages, so recovery must not move the
/// deterministic counters off the static prediction. Exact schemes are
/// equalities; `newton` is an iteration-budget ceiling whose measured
/// count must still satisfy the per-pass structure (4k − 2 stages for k
/// recorded iterations). Byte totals must stay under the proved ceiling
/// and driver collects must be exactly the predicted zero.
#[test]
fn predicted_costs_match_measured_runs_under_chaos() {
    let mut total_retries = 0usize;
    for exec_threads in [1usize, 4] {
        for (geo, block_size) in [16usize, 32].into_iter().enumerate() {
            let fault_seed = 0xA11A + (exec_threads * 10 + geo) as u64;
            let service = SpinService::builder()
                .cluster_config(chaos_config(exec_threads, fault_seed))
                .workers(2)
                .build()
                .unwrap();
            for algo in ["spin", "lu", "cholesky", "newton"] {
                let matrix = if algo == "cholesky" {
                    MatrixSpec::new(N, block_size).seeded(0x5EED).spd()
                } else {
                    MatrixSpec::new(N, block_size).seeded(0x5EED)
                };
                let handle = service
                    .submit(JobSpec::invert(matrix).algorithm(algo).label(algo))
                    .unwrap();

                // The prediction is a property of the plan, not the run:
                // taken here, before the job executes.
                let verdict = handle.analysis().unwrap();
                assert!(
                    verdict.ok(),
                    "{algo} bs={block_size}: verifier found violations: {:?}",
                    verdict.violations()
                );
                let predicted = verdict.analysis.total;
                assert_eq!(predicted.driver_collects, 0, "{algo}: plans never collect");

                // `verify_plans` is armed: a per-node divergence anywhere
                // in the recursion fails the job right here.
                let out = handle.wait().unwrap_or_else(|e| {
                    panic!("{algo} bs={block_size} threads={exec_threads}: {e}")
                });
                let label = format!("{algo} bs={block_size} threads={exec_threads}");
                assert!(
                    out.residual.unwrap() < 1e-6,
                    "{label}: residual {:?}",
                    out.residual
                );

                let stages = out.metrics.total_shuffle_stages();
                if predicted.iterative_ceiling {
                    assert!(
                        stages <= predicted.exchange_stages,
                        "{label}: measured {stages} stages above the {} ceiling",
                        predicted.exchange_stages
                    );
                    // Each pass pays one A·X multiply plus (except the
                    // last) one X·M update: 2 stages per multiply.
                    let reports = out.metrics.convergence();
                    assert_eq!(reports.len(), 1, "{label}: one convergence report");
                    let iters = reports[0].iterations;
                    assert_eq!(
                        stages,
                        4 * iters - 2,
                        "{label}: {iters} iterations must pay exactly 4k-2 stages"
                    );
                } else {
                    assert_eq!(
                        stages, predicted.exchange_stages,
                        "{label}: measured stages diverged from the proof"
                    );
                }
                assert!(
                    out.metrics.total_shuffle_bytes() <= predicted.shuffle_bytes_ceiling,
                    "{label}: measured {} shuffle bytes above the proved ceiling {}",
                    out.metrics.total_shuffle_bytes(),
                    predicted.shuffle_bytes_ceiling
                );
                assert_eq!(out.metrics.driver_collects(), 0, "{label}: collect on the job path");
            }
            total_retries += service.metrics().resilience().retries;
        }
    }
    // The chaos legs must actually have exercised recovery, or the
    // "retries don't move the counters" half of the property is vacuous.
    assert!(total_retries > 0, "fault injection never fired");
}

/// Golden stage/round table: the analyzer rederives the paper's closed
/// forms from plan structure alone — spin 6(b−1) rounds, lu and cholesky
/// their recurrences — at every grid the bench measures. These are the
/// same numbers `docs/ALGORITHMS.md` cites and `BENCH_spin.json` gates.
#[test]
fn analyzer_reproduces_closed_form_stage_table() {
    let session = SpinSession::local(4).unwrap();
    let table: [(&str, [(usize, usize, usize); 3]); 3] = [
        ("spin", [(2, 12, 6), (4, 36, 18), (8, 84, 42)]),
        ("lu", [(2, 16, 8), (4, 52, 26), (8, 140, 70)]),
        ("cholesky", [(2, 10, 5), (4, 30, 15), (8, 78, 39)]),
    ];
    for (algo, rows) in table {
        for (b, stages, rounds) in rows {
            let verdict = session.analyze_invert(algo, N, N / b).unwrap();
            assert!(verdict.ok(), "{algo} b={b}: {:?}", verdict.violations());
            let t = verdict.analysis.total;
            assert_eq!(
                (t.exchange_stages, t.multiply_rounds),
                (stages, rounds),
                "{algo} b={b}"
            );
            assert!(!t.iterative_ceiling, "{algo} is exact, not a ceiling");
            assert_eq!(t.exchange_stages, 2 * t.multiply_rounds, "only multiplies shuffle");
            assert_eq!(t.driver_collects, 0);
            assert!(verdict.analysis.partitioner_proved, "{algo} b={b}");
            assert!(verdict.analysis.opaque_inverts.is_empty(), "{algo} b={b}");
        }
    }
    // Newton at the session's default budget (max_iters = 64): a
    // 2·(2·64 − 1) = 254 exchange-stage SLA ceiling, flagged as such.
    let verdict = session.analyze_invert("newton", N, 32).unwrap();
    let t = verdict.analysis.total;
    assert!(t.iterative_ceiling);
    assert_eq!(t.exchange_stages, 4 * 64 - 2);
    assert_eq!(t.multiply_rounds, 2 * 64 - 1);
}

/// Byte-ceiling goldens: the per-node bound `2·8·γ·m²` summed over the
/// unfolded recursion collapses to `16·bs²·W(b)` with W the per-scheme
/// cubic-weight recurrence — the exact values committed in
/// `BENCH_spin.json`'s `total_shuffle_bytes` gate column.
#[test]
fn analyzer_byte_ceilings_match_committed_gate_values() {
    let session = SpinSession::local(4).unwrap();
    for (algo, bs, bytes) in [
        ("spin", 16usize, 2_064_384u64),  // b=8: 16·256·504
        ("spin", 32, 983_040),            // b=4: 16·1024·60
        ("lu", 32, 2_260_992),            // b=4: 16·1024·138
        ("cholesky", 32, 1_736_704),      // b=4: 16·1024·106
    ] {
        let verdict = session.analyze_invert(algo, N, bs).unwrap();
        assert_eq!(
            verdict.analysis.total.shuffle_bytes_ceiling,
            bytes,
            "{algo} bs={bs}"
        );
    }
}
