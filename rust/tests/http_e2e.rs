//! End-to-end tests of the HTTP job API: a real server on an ephemeral
//! port, a real `TcpStream` client, SSE streams followed to their
//! terminal event, and the durable job log driven through a simulated
//! crash + restart.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use spin::config::{ClusterConfig, HttpConfig};
use spin::http::{HttpClient, HttpServer, ServerState};
use spin::ser::json::Json;
use spin::service::SpinService;
use spin::store::JobLog;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("spin_http_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn http_config() -> HttpConfig {
    HttpConfig {
        listen: "127.0.0.1:0".to_string(),
        // Fast heartbeats so the SSE idle path is exercised in-test.
        sse_heartbeat_ms: 50,
        ..HttpConfig::default()
    }
}

fn bind(service: SpinService) -> HttpServer {
    HttpServer::bind(ServerState::new(service, http_config())).unwrap()
}

fn invert_spec_json(n: usize, bs: usize, seed: u64, tenant: &str) -> String {
    format!(
        r#"{{"kind":"invert","tenant":"{tenant}","label":"e2e","matrix":{{"n":{n},"block_size":{bs},"seed":{seed}}}}}"#
    )
}

/// Drive one request over a raw `TcpStream` — no client sugar — and
/// return (status line, body).
fn raw_request(addr: &str, method: &str, path: &str, body: &str) -> (String, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    stream.flush().unwrap();
    let mut raw = String::new();
    stream.read_to_string(&mut raw).unwrap();
    let (head, body) = raw.split_once("\r\n\r\n").expect("complete response");
    (head.lines().next().unwrap().to_string(), body.to_string())
}

#[test]
fn submit_over_raw_tcp_then_sse_to_terminal_with_residual() {
    let service = SpinService::builder().workers(2).build().unwrap();
    let server = bind(service);
    let addr = server.local_addr().to_string();

    // Submit over a bare socket: the wire format itself is under test.
    let (status_line, body) = raw_request(
        &addr,
        "POST",
        "/v1/jobs",
        &invert_spec_json(32, 8, 7, "alice"),
    );
    assert!(status_line.contains("202"), "{status_line} {body}");
    let reply = Json::parse(&body).unwrap();
    let id = reply.req("id").unwrap().as_i64().unwrap() as u64;
    assert!(id > 0);

    // Follow the event stream to the terminal transition.
    let client = HttpClient::new(addr.clone());
    let events = client.follow_events(&format!("/v1/jobs/{id}/events")).unwrap();
    let phases: Vec<&str> = events
        .iter()
        .filter(|(name, _)| name == "phase")
        .map(|(_, data)| data.req("status").unwrap().as_str().unwrap())
        .collect();
    assert_eq!(phases, vec!["queued", "running", "completed"], "{events:?}");
    assert_eq!(events.last().unwrap().0, "end");
    // Seq strictly increases across the stream (no duplicate delivery).
    let seqs: Vec<i64> = events
        .iter()
        .filter(|(name, _)| name == "phase")
        .map(|(_, data)| data.req("seq").unwrap().as_i64().unwrap())
        .collect();
    assert!(seqs.windows(2).all(|w| w[0] < w[1]), "{seqs:?}");

    // Status: terminal summary carries the inversion residual, and the
    // lazy-leaf invariant holds over HTTP.
    let (code, status) = client.get(&format!("/v1/jobs/{id}")).unwrap();
    assert_eq!(code, 200);
    assert_eq!(status.req("status").unwrap().as_str(), Some("completed"));
    assert!(status.req("residual").unwrap().as_f64().unwrap() < 1e-8);
    assert_eq!(status.req("submit_driver_blocks").unwrap().as_i64(), Some(0));
    let history = status.req("history").unwrap().as_array().unwrap();
    assert_eq!(history.len(), 3, "queued, running, completed");

    // Per-job metrics + explain + global metrics all answer.
    let (code, m) = client.get(&format!("/v1/jobs/{id}/metrics")).unwrap();
    assert_eq!(code, 200);
    assert!(m.req("methods").unwrap().get("multiply").is_some());
    let (code, e) = client.get(&format!("/v1/jobs/{id}/explain")).unwrap();
    assert_eq!(code, 200);
    assert!(e.req("explain").unwrap().as_str().unwrap().contains("invert"));
    let (code, g) = client.get("/v1/metrics").unwrap();
    assert_eq!(code, 200);
    assert_eq!(g.req("workers").unwrap().as_i64(), Some(2));
    assert!(g.req("plan_cache").unwrap().get("entries").is_some());
}

#[test]
fn newton_job_reports_convergence_over_http() {
    let service = SpinService::builder().workers(1).build().unwrap();
    let server = bind(service);
    let client = HttpClient::new(server.local_addr().to_string());

    let spec = Json::parse(
        r#"{"kind":"invert","tenant":"t","algo":"newton","tolerance":1e-8,"max_iters":60,"matrix":{"n":32,"block_size":8,"generator":"spd","seed":5}}"#,
    )
    .unwrap();
    let (code, reply) = client.post("/v1/jobs", Some(&spec)).unwrap();
    assert_eq!(code, 202, "{reply:?}");
    let id = reply.req("id").unwrap().as_i64().unwrap() as u64;
    let events = client.follow_events(&format!("/v1/jobs/{id}/events")).unwrap();
    let last_phase = events
        .iter()
        .rev()
        .find(|(name, _)| name == "phase")
        .unwrap();
    assert_eq!(last_phase.1.req("status").unwrap().as_str(), Some("completed"));

    // Per-job metrics carry the run's residual trajectory.
    let (code, m) = client.get(&format!("/v1/jobs/{id}/metrics")).unwrap();
    assert_eq!(code, 200);
    let conv = m.req("convergence").unwrap();
    assert_eq!(conv.req("runs").unwrap().as_i64(), Some(1), "{conv:?}");
    assert_eq!(conv.req("converged_runs").unwrap().as_i64(), Some(1));
    let reports = conv.req("reports").unwrap().as_array().unwrap();
    assert_eq!(reports.len(), 1);
    let r = &reports[0];
    assert_eq!(r.req("algo").unwrap().as_str(), Some("newton"));
    assert_eq!(r.req("converged").unwrap().as_bool(), Some(true));
    let iters = r.req("iterations").unwrap().as_i64().unwrap();
    assert!((1..60).contains(&iters), "early stop expected, got {iters}");
    let residuals = r.req("residuals").unwrap().as_array().unwrap();
    assert_eq!(residuals.len() as i64, iters);
    assert!(r.req("final_residual").unwrap().as_f64().unwrap() <= 1e-8);

    // The service-wide view aggregates the same run.
    let (code, g) = client.get("/v1/metrics").unwrap();
    assert_eq!(code, 200);
    let total = g.req("convergence").unwrap();
    assert_eq!(total.req("runs").unwrap().as_i64(), Some(1), "{total:?}");
    assert_eq!(total.req("converged_runs").unwrap().as_i64(), Some(1));
    assert!(total.req("iterations").unwrap().as_i64().unwrap() >= 1);
}

#[test]
fn strict_specs_and_routing_errors_over_http() {
    let service = SpinService::builder().workers(0).build().unwrap();
    let server = bind(service);
    let client = HttpClient::new(server.local_addr().to_string());

    // Unknown JobSpec field: rejected, naming the offending key.
    let bad = Json::parse(
        r#"{"kind":"invert","tenant":"t","matirx":{"n":32,"block_size":8}}"#,
    )
    .unwrap();
    let (code, body) = client.post("/v1/jobs", Some(&bad)).unwrap();
    assert_eq!(code, 400, "{body:?}");
    assert!(body.req("error").unwrap().as_str().unwrap().contains("matirx"));

    // Unknown algorithm: 400, and the body lists what IS registered.
    let bad_algo = Json::parse(
        r#"{"kind":"invert","tenant":"t","algo":"qr","matrix":{"n":32,"block_size":8}}"#,
    )
    .unwrap();
    let (code, body) = client.post("/v1/jobs", Some(&bad_algo)).unwrap();
    assert_eq!(code, 400, "{body:?}");
    let msg = body.req("error").unwrap().as_str().unwrap().to_string();
    assert!(msg.contains("qr"), "{msg}");
    assert!(msg.contains("cholesky|lu|newton|spin"), "{msg}");

    // Iterative knobs on an exact algorithm: 400 naming the mismatch.
    let exact_tol = Json::parse(
        r#"{"kind":"invert","tenant":"t","algo":"spin","tolerance":1e-8,"matrix":{"n":32,"block_size":8}}"#,
    )
    .unwrap();
    let (code, body) = client.post("/v1/jobs", Some(&exact_tol)).unwrap();
    assert_eq!(code, 400, "{body:?}");
    assert!(
        body.req("error")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("iterative"),
        "{body:?}"
    );

    // Malformed JSON, bad routes, wrong methods, unknown ids.
    let (line, _) = raw_request(&client_addr(&server), "POST", "/v1/jobs", "{nope");
    assert!(line.contains("400"), "{line}");
    assert_eq!(client.get("/v1/jobs/999").unwrap().0, 404);
    assert_eq!(client.get("/v1/jobs/zzz").unwrap().0, 400);
    assert_eq!(client.get("/nope").unwrap().0, 404);
    assert_eq!(client.post("/v1/metrics", None).unwrap().0, 405);
    assert_eq!(client.get("/v1/healthz").unwrap().0, 200);

    // Oversized body: 413 from the declared Content-Length alone, before
    // any body bytes are read (so none are sent here).
    let mut stream = TcpStream::connect(client_addr(&server)).unwrap();
    write!(
        stream,
        "POST /v1/jobs HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
        2 << 20
    )
    .unwrap();
    stream.flush().unwrap();
    let mut raw = String::new();
    stream.read_to_string(&mut raw).unwrap();
    assert!(raw.starts_with("HTTP/1.1 413"), "{raw}");
}

fn client_addr(server: &HttpServer) -> String {
    server.local_addr().to_string()
}

#[test]
fn cancel_over_http_reaches_sse_and_log() {
    let dir = tmp_dir("cancel");
    let (log, replay) = JobLog::open(&dir).unwrap();
    assert_eq!(replay.jobs.len(), 0);
    // No workers: the job stays queued, so cancel always wins.
    let service = SpinService::builder()
        .workers(0)
        .job_log(Arc::new(log))
        .build()
        .unwrap();
    let server = bind(service);
    let client = HttpClient::new(server.local_addr().to_string());

    let spec = Json::parse(&invert_spec_json(32, 8, 9, "bob")).unwrap();
    let (code, reply) = client.post("/v1/jobs", Some(&spec)).unwrap();
    assert_eq!(code, 202);
    let id = reply.req("id").unwrap().as_i64().unwrap() as u64;
    let (code, c) = client.post(&format!("/v1/jobs/{id}/cancel"), None).unwrap();
    assert_eq!(code, 200);
    assert_eq!(c.req("cancelled").unwrap().as_bool(), Some(true));
    let events = client.follow_events(&format!("/v1/jobs/{id}/events")).unwrap();
    let last_phase = events
        .iter()
        .rev()
        .find(|(name, _)| name == "phase")
        .unwrap();
    assert_eq!(last_phase.1.req("status").unwrap().as_str(), Some("cancelled"));

    // Explicit cancels are durable: a restart must not resurrect the job.
    drop(server);
    let (_log2, replay) = JobLog::open(&dir).unwrap();
    assert_eq!(replay.pending().count(), 0);
    let job = replay.jobs.iter().find(|j| j.id == id).unwrap();
    assert_eq!(
        job.terminal.as_ref().unwrap().status,
        spin::service::JobStatus::Cancelled
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// The acceptance scenario: jobs before a crash, kill, restart against
/// the same store — every job terminal exactly once, SSE works on both
/// sides of the restart, and no terminal job re-executes.
#[test]
fn kill_and_restart_replays_log_without_duplicate_execution() {
    let dir = tmp_dir("restart");

    // Generation 1: one job runs to completion, one stays pending.
    let (log, _) = JobLog::open(&dir).unwrap();
    let service = SpinService::builder()
        .workers(0)
        .job_log(Arc::new(log))
        .build()
        .unwrap();
    let server = bind(service);
    let client = HttpClient::new(server.local_addr().to_string());

    let spec_a = Json::parse(&invert_spec_json(32, 8, 5, "alice")).unwrap();
    let (code, reply) = client.post("/v1/jobs", Some(&spec_a)).unwrap();
    assert_eq!(code, 202);
    let id_a = reply.req("id").unwrap().as_i64().unwrap() as u64;
    server.service().run_pending(); // A completes before the crash
    let events_a = client.follow_events(&format!("/v1/jobs/{id_a}/events")).unwrap();
    assert_eq!(
        events_a
            .iter()
            .rev()
            .find(|(n, _)| n == "phase")
            .unwrap()
            .1
            .req("status")
            .unwrap()
            .as_str(),
        Some("completed")
    );
    let residual_a = {
        let (_, s) = client.get(&format!("/v1/jobs/{id_a}")).unwrap();
        s.req("residual").unwrap().as_f64().unwrap()
    };
    let spec_b = Json::parse(&invert_spec_json(64, 16, 6, "bob")).unwrap();
    let (code, reply) = client.post("/v1/jobs", Some(&spec_b)).unwrap();
    assert_eq!(code, 202);
    let id_b = reply.req("id").unwrap().as_i64().unwrap() as u64;

    // Crash: drop server + service with B still queued. The shutdown
    // drain cancels B in-process but deliberately does NOT log a
    // terminal record — B must be re-enqueued by the replay.
    drop(server);

    // Generation 2: replay the log the way `spin serve --http` does.
    let (log, replay) = JobLog::open(&dir).unwrap();
    assert_eq!(log.generation(), 2, "one prior generation");
    let service = SpinService::builder()
        .workers(0)
        .job_log(Arc::new(log))
        .build()
        .unwrap();
    let mut recovered = std::collections::BTreeMap::new();
    let mut pending = Vec::new();
    for job in replay.jobs {
        match job.terminal {
            Some(t) => {
                recovered.insert(
                    job.id,
                    spin::http::RecoveredJob {
                        spec: job.spec,
                        terminal: spin::service::TerminalSummary {
                            status: t.status,
                            error: t.error,
                            residual: t.residual,
                        },
                    },
                );
            }
            None => pending.push((job.id, job.spec)),
        }
    }
    assert_eq!(recovered.len(), 1, "A is terminal in the log");
    assert_eq!(pending.len(), 1, "B is pending in the log");
    for (id, spec) in pending {
        assert_eq!(id, id_b);
        service.submit_with_id(id, spec).unwrap();
    }
    let mut state = ServerState::new(service, http_config());
    state.recovered = recovered;
    state.generation = 2;
    let server = HttpServer::bind(state).unwrap();
    let client = HttpClient::new(server.local_addr().to_string());

    // A answers from the log — marked recovered, same residual, and an
    // idempotent resubmit under its id returns 200 without re-running.
    let (code, s) = client.get(&format!("/v1/jobs/{id_a}")).unwrap();
    assert_eq!(code, 200);
    assert_eq!(s.req("recovered").unwrap().as_bool(), Some(true));
    assert_eq!(s.req("residual").unwrap().as_f64(), Some(residual_a));
    let mut resubmit_a = spec_a.as_object().unwrap().clone();
    resubmit_a.insert("id".to_string(), Json::num(id_a as f64));
    let (code, s) = client.post("/v1/jobs", Some(&Json::Object(resubmit_a))).unwrap();
    assert_eq!(code, 200, "{s:?}");
    assert_eq!(s.req("recovered").unwrap().as_bool(), Some(true));
    assert!(server.service().job(id_a).is_none(), "A never re-entered the service");

    // SSE works after the restart: follow B through execution.
    let follower = {
        let client = client.clone();
        let path = format!("/v1/jobs/{id_b}/events");
        std::thread::spawn(move || client.follow_events(&path).unwrap())
    };
    server.service().run_pending();
    let events_b = follower.join().unwrap();
    assert_eq!(
        events_b
            .iter()
            .rev()
            .find(|(n, _)| n == "phase")
            .unwrap()
            .1
            .req("status")
            .unwrap()
            .as_str(),
        Some("completed")
    );
    drop(server);

    // Exactly-once: the raw log holds one terminal record per job.
    let text = std::fs::read_to_string(dir.join("jobs.log")).unwrap();
    let terminals = |id: u64| {
        text.lines()
            .filter(|l| l.contains("\"type\":\"terminal\"") && l.contains(&format!("\"id\":{id},")))
            .count()
    };
    assert_eq!(terminals(id_a), 1);
    assert_eq!(terminals(id_b), 1);
    // And a third replay sees nothing pending.
    let (_log, replay) = JobLog::open(&dir).unwrap();
    assert_eq!(replay.pending().count(), 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The checkpoint/resume acceptance scenario against the real binary:
/// a deep inversion starts under `checkpoint_every_level=1`, the server
/// is SIGKILLed once the journal shows completed recursion levels, and
/// the restarted server resumes the job from those checkpoints — it
/// restores instead of recomputing (visible in the per-job recovery
/// counters), finishes with a passing residual, and the result is
/// bit-identical to an uninterrupted fault-free run.
#[test]
fn binary_kill_mid_job_resumes_from_checkpointed_levels() {
    let dir = tmp_dir("ckpt_kill");
    let serve_args = |dir: &PathBuf| {
        vec![
            "serve".to_string(),
            "--http".to_string(),
            "127.0.0.1:0".to_string(),
            "--workers".to_string(),
            "1".to_string(),
            "--store".to_string(),
            dir.to_str().unwrap().to_string(),
            "--set".to_string(),
            "checkpoint_every_level=1".to_string(),
        ]
    };
    let spawn_server = |dir: &PathBuf| {
        let child = Command::new(env!("CARGO_BIN_EXE_spin"))
            .args(serve_args(dir))
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .unwrap();
        let mut child = KillOnDrop(child);
        let stdout = child.0.stdout.take().unwrap();
        let mut lines = BufReader::new(stdout).lines();
        let mut addr = None;
        let mut log_line = None;
        while addr.is_none() || log_line.is_none() {
            let line = lines
                .next()
                .expect("server exited before printing its banner")
                .unwrap();
            if let Some(rest) = line.strip_prefix("listening on http://") {
                addr = Some(rest.trim().to_string());
            } else if line.starts_with("job log:") {
                log_line = Some(line);
            }
        }
        (child, addr.unwrap(), log_line.unwrap())
    };

    // Generation 1: a 32×32-grid inversion — deep recursion, so inner
    // levels checkpoint long before the job can finish.
    let (child, addr, _) = spawn_server(&dir);
    let client = HttpClient::new(addr);
    let spec = Json::parse(&invert_spec_json(256, 8, 21, "chaos")).unwrap();
    let (code, reply) = client.post("/v1/jobs", Some(&spec)).unwrap();
    assert_eq!(code, 202, "{reply:?}");
    let id = reply.req("id").unwrap().as_i64().unwrap() as u64;

    // Kill -9 the moment a complete `checkpoint` record is journaled:
    // the disk now holds a mid-job crash state.
    let log_path = dir.join("jobs.log");
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let text = std::fs::read_to_string(&log_path).unwrap_or_default();
        if text
            .lines()
            .any(|l| l.contains("\"type\":\"checkpoint\"") && l.ends_with('}'))
        {
            break;
        }
        assert!(Instant::now() < deadline, "no checkpoint journaled in time");
        std::thread::sleep(Duration::from_millis(2));
    }
    drop(child); // SIGKILL
    let text = std::fs::read_to_string(&log_path).unwrap();
    assert!(
        !text.contains("\"type\":\"terminal\""),
        "the job must not have finished before the kill:\n{text}"
    );

    // Generation 2: same store — the banner reports the resume, and the
    // job runs to a passing terminal by restoring the journaled levels.
    let (child, addr, log_line) = spawn_server(&dir);
    assert!(
        log_line.contains("1 pending job(s) resumed"),
        "{log_line}"
    );
    let client = HttpClient::new(addr);
    let deadline = Instant::now() + Duration::from_secs(120);
    let residual = loop {
        let (code, s) = client.get(&format!("/v1/jobs/{id}")).unwrap();
        assert_eq!(code, 200);
        match s.req("status").unwrap().as_str().unwrap() {
            "completed" => break s.req("residual").unwrap().as_f64().unwrap(),
            "queued" | "running" => {}
            other => panic!("unexpected terminal `{other}`: {s:?}"),
        }
        assert!(Instant::now() < deadline, "resumed job never finished");
        std::thread::sleep(Duration::from_millis(50));
    };
    assert!(residual < 1e-8, "residual {residual}");
    // The resumed run provably skipped work: recursion levels were
    // restored from the checkpoint store, not recomputed.
    let (code, m) = client.get(&format!("/v1/jobs/{id}/metrics")).unwrap();
    assert_eq!(code, 200);
    let restored = m
        .req("resilience")
        .unwrap()
        .req("checkpoints_restored")
        .unwrap()
        .as_i64()
        .unwrap();
    assert!(restored >= 1, "{m:?}");
    // Terminal cleanup reclaimed the checkpoint store.
    assert!(
        !dir.join("checkpoints").join(format!("job_{id}")).exists(),
        "checkpoints deleted once the job is terminal"
    );
    drop(child);

    // Bit-identity: an uninterrupted run of the same spec, no faults,
    // no checkpoints, produces the same result bits (equal residual).
    let clean = SpinService::builder().workers(2).build().unwrap();
    let handle = clean
        .submit(spin::service::JobSpec::from_json(&spec).unwrap())
        .unwrap();
    let out = handle.wait().unwrap();
    assert_eq!(
        out.residual.unwrap().to_bits(),
        residual.to_bits(),
        "resumed result must be bit-identical to a clean run"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Kill the spawned server even when an assert panics mid-test.
struct KillOnDrop(Child);

impl Drop for KillOnDrop {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

/// CI smoke: launch the real `spin` binary, parse the printed address,
/// and drive the API from outside the process.
#[test]
fn binary_serve_http_smoke() {
    let dir = tmp_dir("smoke");
    let child = Command::new(env!("CARGO_BIN_EXE_spin"))
        .args([
            "serve",
            "--http",
            "127.0.0.1:0",
            "--workers",
            "2",
            "--store",
            dir.to_str().unwrap(),
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .unwrap();
    let mut child = KillOnDrop(child);
    let stdout = child.0.stdout.take().unwrap();
    let mut lines = BufReader::new(stdout).lines();
    let addr = loop {
        let line = lines
            .next()
            .expect("server exited before printing its address")
            .unwrap();
        if let Some(rest) = line.strip_prefix("listening on http://") {
            break rest.trim().to_string();
        }
    };
    let client = HttpClient::new(addr);

    let (code, h) = client.get("/v1/healthz").unwrap();
    assert_eq!(code, 200);
    assert_eq!(h.req("ok").unwrap().as_bool(), Some(true));

    let spec = Json::parse(&invert_spec_json(32, 8, 11, "smoke")).unwrap();
    let (code, reply) = client.post("/v1/jobs", Some(&spec)).unwrap();
    assert_eq!(code, 202, "{reply:?}");
    let id = reply.req("id").unwrap().as_i64().unwrap();

    // Poll status to terminal (the SSE path is covered in-process).
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let (code, s) = client.get(&format!("/v1/jobs/{id}")).unwrap();
        assert_eq!(code, 200);
        let status = s.req("status").unwrap().as_str().unwrap().to_string();
        if status == "completed" {
            assert!(s.req("residual").unwrap().as_f64().unwrap() < 1e-8);
            break;
        }
        assert!(
            status == "queued" || status == "running",
            "unexpected terminal: {s:?}"
        );
        assert!(Instant::now() < deadline, "job never finished");
        std::thread::sleep(Duration::from_millis(50));
    }

    // Cancel answers 2xx whatever the race outcome; metrics answer.
    let (code, _) = client.post(&format!("/v1/jobs/{id}/cancel"), None).unwrap();
    assert_eq!(code, 200);
    let (code, g) = client.get("/v1/metrics").unwrap();
    assert_eq!(code, 200);
    assert_eq!(g.req("generation").unwrap().as_i64(), Some(1));
    drop(child);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A tenant over its queue quota gets 429 + `Retry-After` — scoped
/// backpressure that tells exactly one client to slow down — while
/// other tenants keep getting 202, and the per-tenant gauges surface in
/// `/v1/metrics`.
#[test]
fn tenant_over_quota_gets_429_with_retry_after() {
    let mut cfg = ClusterConfig::local(2);
    cfg.tenant_queue_quota = 1;
    let service = SpinService::builder()
        .cluster_config(cfg)
        .workers(0)
        .queue_capacity(16)
        .build()
        .unwrap();
    let server = bind(service);
    let addr = server.local_addr().to_string();
    let client = HttpClient::new(addr.clone());

    let spec1 = Json::parse(&invert_spec_json(16, 4, 1, "flooder")).unwrap();
    assert_eq!(client.post("/v1/jobs", Some(&spec1)).unwrap().0, 202);
    let (_, g) = client.get("/v1/metrics").unwrap();
    let tenants = g.req("tenants").unwrap().as_array().unwrap();
    let flooder = tenants
        .iter()
        .find(|t| t.req("tenant").unwrap().as_str() == Some("flooder"))
        .expect("gauge for the queued tenant");
    assert_eq!(flooder.req("queued").unwrap().as_i64(), Some(1));

    // Second queued job for the same tenant: read the raw response so
    // the Retry-After header itself is under test.
    let spec2 = Json::parse(&invert_spec_json(16, 4, 2, "flooder")).unwrap();
    let body = spec2.compact();
    let mut stream = TcpStream::connect(&addr).unwrap();
    write!(
        stream,
        "POST /v1/jobs HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    stream.flush().unwrap();
    let mut raw = String::new();
    stream.read_to_string(&mut raw).unwrap();
    let (head, body) = raw.split_once("\r\n\r\n").unwrap();
    assert!(head.starts_with("HTTP/1.1 429 Too Many Requests"), "{raw}");
    assert!(head.contains("Retry-After: 1"), "{raw}");
    assert!(body.contains("queue quota"), "{raw}");

    // The quota is per tenant: someone else is still welcome.
    let other = Json::parse(&invert_spec_json(16, 4, 3, "patient")).unwrap();
    assert_eq!(client.post("/v1/jobs", Some(&other)).unwrap().0, 202);

    // Draining the queue frees the quota: the flooder may retry now.
    server.service().run_pending();
    let spec3 = Json::parse(&invert_spec_json(16, 4, 4, "flooder")).unwrap();
    assert_eq!(client.post("/v1/jobs", Some(&spec3)).unwrap().0, 202);
}

/// The chaos acceptance run: 20 seeded jobs over HTTP under
/// deterministic fault injection (`fault_rate=0.05`, panics + errors +
/// stragglers). Every job must terminate successfully with passing
/// residuals, the recovery counters must show retries actually
/// happened, and — because retry/speculation are virtual-time replays,
/// never second executions — every residual must be BIT-identical to a
/// fault-free run of the same spec.
#[test]
fn chaos_20_jobs_over_http_recover_and_match_fault_free_bits() {
    let tenants = ["alice", "bob", "carol", "dave"];
    let specs: Vec<String> = (0..20u64)
        .map(|i| invert_spec_json(32, 8, 500 + (i % 6), tenants[(i % 4) as usize]))
        .collect();
    let run = |cfg: ClusterConfig| -> (Vec<f64>, Vec<i64>) {
        let service = SpinService::builder()
            .cluster_config(cfg)
            .workers(2)
            .queue_capacity(32)
            .build()
            .unwrap();
        let server = bind(service);
        let client = HttpClient::new(server.local_addr().to_string());
        let mut ids = Vec::new();
        for (i, spec) in specs.iter().enumerate() {
            let spec = Json::parse(spec).unwrap();
            let (code, reply) = client.post("/v1/jobs", Some(&spec)).unwrap();
            assert_eq!(code, 202, "submit {i}: {reply:?}");
            ids.push(reply.req("id").unwrap().as_i64().unwrap() as u64);
        }
        server.service().wait_idle();
        let mut residuals = Vec::new();
        for (i, id) in ids.iter().enumerate() {
            let (code, s) = client.get(&format!("/v1/jobs/{id}")).unwrap();
            assert_eq!(code, 200);
            assert_eq!(
                s.req("status").unwrap().as_str(),
                Some("completed"),
                "job {i}: {s:?}"
            );
            let r = s.req("residual").unwrap().as_f64().unwrap();
            assert!(r < 1e-8, "job {i} residual {r}");
            residuals.push(r);
        }
        let (code, g) = client.get("/v1/metrics").unwrap();
        assert_eq!(code, 200);
        let res = g.req("resilience").unwrap();
        let counters = [
            "retries",
            "retry_exhausted",
            "speculative_launched",
            "speculative_won",
        ]
        .iter()
        .map(|name| res.req(name).unwrap().as_i64().unwrap())
        .collect();
        (residuals, counters)
    };

    // CI sweeps several fault streams by exporting SPIN_CHAOS_SEED; the
    // default keeps a bare `cargo test` deterministic.
    let fault_seed = std::env::var("SPIN_CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE);
    let mut chaos = ClusterConfig::local(2);
    chaos.fault_seed = Some(fault_seed);
    chaos.fault_rate = 0.05;
    // A deeper budget than the default: ~10^4 task attempts across the
    // run make 4-in-a-row fault streaks (p = 0.05^4) plausible; six
    // in a row are not.
    chaos.task_retries = 5;
    let (faulted, counters) = run(chaos);
    let (retries, exhausted, spec_launched, spec_won) =
        (counters[0], counters[1], counters[2], counters[3]);
    assert!(retries > 0, "chaos run injected and recovered faults");
    assert_eq!(exhausted, 0, "every job stayed inside the retry budget");
    assert!(spec_won >= 0 && spec_won <= spec_launched, "{counters:?}");

    // Fault-free arm: identical specs, injection disarmed. The
    // resilience machinery must be provably inert (zero counters) and
    // the results bit-identical (residuals are a pure function of the
    // result bits, and f64 round-trips the API's JSON exactly).
    let (clean, counters) = run(ClusterConfig::local(2));
    assert_eq!(counters, vec![0, 0, 0, 0], "fault injection is inert when off");
    for (i, (f, c)) in faulted.iter().zip(&clean).enumerate() {
        assert_eq!(
            f.to_bits(),
            c.to_bits(),
            "job {i}: faulted residual {f:e} != clean {c:e}"
        );
    }
}

/// 50 jobs over HTTP across tenants: every one reaches `completed`, the
/// retention counters stay bounded, and the driver never materializes a
/// block at submit.
#[test]
fn http_soak_50_jobs_across_tenants() {
    let service = SpinService::builder()
        .workers(2)
        .queue_capacity(64)
        .build()
        .unwrap();
    let server = bind(service);
    let client = HttpClient::new(server.local_addr().to_string());
    let tenants = ["alice", "bob", "carol", "dave"];
    let mut ids = Vec::new();
    for i in 0..50u64 {
        let spec = Json::parse(&invert_spec_json(
            32,
            8,
            100 + (i % 8),
            tenants[(i % 4) as usize],
        ))
        .unwrap();
        let (code, reply) = client.post("/v1/jobs", Some(&spec)).unwrap();
        assert_eq!(code, 202, "submit {i}: {reply:?}");
        ids.push(reply.req("id").unwrap().as_i64().unwrap() as u64);
    }
    server.service().wait_idle();
    for id in &ids {
        let (code, s) = client.get(&format!("/v1/jobs/{id}")).unwrap();
        assert_eq!(code, 200);
        assert_eq!(s.req("status").unwrap().as_str(), Some("completed"), "{s:?}");
        assert_eq!(s.req("submit_driver_blocks").unwrap().as_i64(), Some(0));
    }
    let (code, g) = client.get("/v1/metrics").unwrap();
    assert_eq!(code, 200);
    // Retention: finished jobs release their stage records; the resident
    // window stays far below 50 jobs' worth of stages.
    let retained = g.req("retained_stage_records").unwrap().as_i64().unwrap();
    let released = g.req("released_stage_records").unwrap().as_i64().unwrap();
    assert!(released > 0, "{g:?}");
    assert!(retained <= released, "retained {retained} vs released {released}");
}
