//! End-to-end tests of the HTTP job API: a real server on an ephemeral
//! port, a real `TcpStream` client, SSE streams followed to their
//! terminal event, and the durable job log driven through a simulated
//! crash + restart.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use spin::config::HttpConfig;
use spin::http::{HttpClient, HttpServer, ServerState};
use spin::ser::json::Json;
use spin::service::SpinService;
use spin::store::JobLog;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("spin_http_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn http_config() -> HttpConfig {
    HttpConfig {
        listen: "127.0.0.1:0".to_string(),
        // Fast heartbeats so the SSE idle path is exercised in-test.
        sse_heartbeat_ms: 50,
        ..HttpConfig::default()
    }
}

fn bind(service: SpinService) -> HttpServer {
    HttpServer::bind(ServerState::new(service, http_config())).unwrap()
}

fn invert_spec_json(n: usize, bs: usize, seed: u64, tenant: &str) -> String {
    format!(
        r#"{{"kind":"invert","tenant":"{tenant}","label":"e2e","matrix":{{"n":{n},"block_size":{bs},"seed":{seed}}}}}"#
    )
}

/// Drive one request over a raw `TcpStream` — no client sugar — and
/// return (status line, body).
fn raw_request(addr: &str, method: &str, path: &str, body: &str) -> (String, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    stream.flush().unwrap();
    let mut raw = String::new();
    stream.read_to_string(&mut raw).unwrap();
    let (head, body) = raw.split_once("\r\n\r\n").expect("complete response");
    (head.lines().next().unwrap().to_string(), body.to_string())
}

#[test]
fn submit_over_raw_tcp_then_sse_to_terminal_with_residual() {
    let service = SpinService::builder().workers(2).build().unwrap();
    let server = bind(service);
    let addr = server.local_addr().to_string();

    // Submit over a bare socket: the wire format itself is under test.
    let (status_line, body) = raw_request(
        &addr,
        "POST",
        "/v1/jobs",
        &invert_spec_json(32, 8, 7, "alice"),
    );
    assert!(status_line.contains("202"), "{status_line} {body}");
    let reply = Json::parse(&body).unwrap();
    let id = reply.req("id").unwrap().as_i64().unwrap() as u64;
    assert!(id > 0);

    // Follow the event stream to the terminal transition.
    let client = HttpClient::new(addr.clone());
    let events = client.follow_events(&format!("/v1/jobs/{id}/events")).unwrap();
    let phases: Vec<&str> = events
        .iter()
        .filter(|(name, _)| name == "phase")
        .map(|(_, data)| data.req("status").unwrap().as_str().unwrap())
        .collect();
    assert_eq!(phases, vec!["queued", "running", "completed"], "{events:?}");
    assert_eq!(events.last().unwrap().0, "end");
    // Seq strictly increases across the stream (no duplicate delivery).
    let seqs: Vec<i64> = events
        .iter()
        .filter(|(name, _)| name == "phase")
        .map(|(_, data)| data.req("seq").unwrap().as_i64().unwrap())
        .collect();
    assert!(seqs.windows(2).all(|w| w[0] < w[1]), "{seqs:?}");

    // Status: terminal summary carries the inversion residual, and the
    // lazy-leaf invariant holds over HTTP.
    let (code, status) = client.get(&format!("/v1/jobs/{id}")).unwrap();
    assert_eq!(code, 200);
    assert_eq!(status.req("status").unwrap().as_str(), Some("completed"));
    assert!(status.req("residual").unwrap().as_f64().unwrap() < 1e-8);
    assert_eq!(status.req("submit_driver_blocks").unwrap().as_i64(), Some(0));
    let history = status.req("history").unwrap().as_array().unwrap();
    assert_eq!(history.len(), 3, "queued, running, completed");

    // Per-job metrics + explain + global metrics all answer.
    let (code, m) = client.get(&format!("/v1/jobs/{id}/metrics")).unwrap();
    assert_eq!(code, 200);
    assert!(m.req("methods").unwrap().get("multiply").is_some());
    let (code, e) = client.get(&format!("/v1/jobs/{id}/explain")).unwrap();
    assert_eq!(code, 200);
    assert!(e.req("explain").unwrap().as_str().unwrap().contains("invert"));
    let (code, g) = client.get("/v1/metrics").unwrap();
    assert_eq!(code, 200);
    assert_eq!(g.req("workers").unwrap().as_i64(), Some(2));
    assert!(g.req("plan_cache").unwrap().get("entries").is_some());
}

#[test]
fn strict_specs_and_routing_errors_over_http() {
    let service = SpinService::builder().workers(0).build().unwrap();
    let server = bind(service);
    let client = HttpClient::new(server.local_addr().to_string());

    // Unknown JobSpec field: rejected, naming the offending key.
    let bad = Json::parse(
        r#"{"kind":"invert","tenant":"t","matirx":{"n":32,"block_size":8}}"#,
    )
    .unwrap();
    let (code, body) = client.post("/v1/jobs", Some(&bad)).unwrap();
    assert_eq!(code, 400, "{body:?}");
    assert!(body.req("error").unwrap().as_str().unwrap().contains("matirx"));

    // Malformed JSON, bad routes, wrong methods, unknown ids.
    let (line, _) = raw_request(&client_addr(&server), "POST", "/v1/jobs", "{nope");
    assert!(line.contains("400"), "{line}");
    assert_eq!(client.get("/v1/jobs/999").unwrap().0, 404);
    assert_eq!(client.get("/v1/jobs/zzz").unwrap().0, 400);
    assert_eq!(client.get("/nope").unwrap().0, 404);
    assert_eq!(client.post("/v1/metrics", None).unwrap().0, 405);
    assert_eq!(client.get("/v1/healthz").unwrap().0, 200);

    // Oversized body: 413 from the declared Content-Length alone, before
    // any body bytes are read (so none are sent here).
    let mut stream = TcpStream::connect(client_addr(&server)).unwrap();
    write!(
        stream,
        "POST /v1/jobs HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
        2 << 20
    )
    .unwrap();
    stream.flush().unwrap();
    let mut raw = String::new();
    stream.read_to_string(&mut raw).unwrap();
    assert!(raw.starts_with("HTTP/1.1 413"), "{raw}");
}

fn client_addr(server: &HttpServer) -> String {
    server.local_addr().to_string()
}

#[test]
fn cancel_over_http_reaches_sse_and_log() {
    let dir = tmp_dir("cancel");
    let (log, replay) = JobLog::open(&dir).unwrap();
    assert_eq!(replay.jobs.len(), 0);
    // No workers: the job stays queued, so cancel always wins.
    let service = SpinService::builder()
        .workers(0)
        .job_log(Arc::new(log))
        .build()
        .unwrap();
    let server = bind(service);
    let client = HttpClient::new(server.local_addr().to_string());

    let spec = Json::parse(&invert_spec_json(32, 8, 9, "bob")).unwrap();
    let (code, reply) = client.post("/v1/jobs", Some(&spec)).unwrap();
    assert_eq!(code, 202);
    let id = reply.req("id").unwrap().as_i64().unwrap() as u64;
    let (code, c) = client.post(&format!("/v1/jobs/{id}/cancel"), None).unwrap();
    assert_eq!(code, 200);
    assert_eq!(c.req("cancelled").unwrap().as_bool(), Some(true));
    let events = client.follow_events(&format!("/v1/jobs/{id}/events")).unwrap();
    let last_phase = events
        .iter()
        .rev()
        .find(|(name, _)| name == "phase")
        .unwrap();
    assert_eq!(last_phase.1.req("status").unwrap().as_str(), Some("cancelled"));

    // Explicit cancels are durable: a restart must not resurrect the job.
    drop(server);
    let (_log2, replay) = JobLog::open(&dir).unwrap();
    assert_eq!(replay.pending().count(), 0);
    let job = replay.jobs.iter().find(|j| j.id == id).unwrap();
    assert_eq!(
        job.terminal.as_ref().unwrap().status,
        spin::service::JobStatus::Cancelled
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// The acceptance scenario: jobs before a crash, kill, restart against
/// the same store — every job terminal exactly once, SSE works on both
/// sides of the restart, and no terminal job re-executes.
#[test]
fn kill_and_restart_replays_log_without_duplicate_execution() {
    let dir = tmp_dir("restart");

    // Generation 1: one job runs to completion, one stays pending.
    let (log, _) = JobLog::open(&dir).unwrap();
    let service = SpinService::builder()
        .workers(0)
        .job_log(Arc::new(log))
        .build()
        .unwrap();
    let server = bind(service);
    let client = HttpClient::new(server.local_addr().to_string());

    let spec_a = Json::parse(&invert_spec_json(32, 8, 5, "alice")).unwrap();
    let (code, reply) = client.post("/v1/jobs", Some(&spec_a)).unwrap();
    assert_eq!(code, 202);
    let id_a = reply.req("id").unwrap().as_i64().unwrap() as u64;
    server.service().run_pending(); // A completes before the crash
    let events_a = client.follow_events(&format!("/v1/jobs/{id_a}/events")).unwrap();
    assert_eq!(
        events_a
            .iter()
            .rev()
            .find(|(n, _)| n == "phase")
            .unwrap()
            .1
            .req("status")
            .unwrap()
            .as_str(),
        Some("completed")
    );
    let residual_a = {
        let (_, s) = client.get(&format!("/v1/jobs/{id_a}")).unwrap();
        s.req("residual").unwrap().as_f64().unwrap()
    };
    let spec_b = Json::parse(&invert_spec_json(64, 16, 6, "bob")).unwrap();
    let (code, reply) = client.post("/v1/jobs", Some(&spec_b)).unwrap();
    assert_eq!(code, 202);
    let id_b = reply.req("id").unwrap().as_i64().unwrap() as u64;

    // Crash: drop server + service with B still queued. The shutdown
    // drain cancels B in-process but deliberately does NOT log a
    // terminal record — B must be re-enqueued by the replay.
    drop(server);

    // Generation 2: replay the log the way `spin serve --http` does.
    let (log, replay) = JobLog::open(&dir).unwrap();
    assert_eq!(log.generation(), 2, "one prior generation");
    let service = SpinService::builder()
        .workers(0)
        .job_log(Arc::new(log))
        .build()
        .unwrap();
    let mut recovered = std::collections::BTreeMap::new();
    let mut pending = Vec::new();
    for job in replay.jobs {
        match job.terminal {
            Some(t) => {
                recovered.insert(
                    job.id,
                    spin::http::RecoveredJob {
                        spec: job.spec,
                        terminal: spin::service::TerminalSummary {
                            status: t.status,
                            error: t.error,
                            residual: t.residual,
                        },
                    },
                );
            }
            None => pending.push((job.id, job.spec)),
        }
    }
    assert_eq!(recovered.len(), 1, "A is terminal in the log");
    assert_eq!(pending.len(), 1, "B is pending in the log");
    for (id, spec) in pending {
        assert_eq!(id, id_b);
        service.submit_with_id(id, spec).unwrap();
    }
    let mut state = ServerState::new(service, http_config());
    state.recovered = recovered;
    state.generation = 2;
    let server = HttpServer::bind(state).unwrap();
    let client = HttpClient::new(server.local_addr().to_string());

    // A answers from the log — marked recovered, same residual, and an
    // idempotent resubmit under its id returns 200 without re-running.
    let (code, s) = client.get(&format!("/v1/jobs/{id_a}")).unwrap();
    assert_eq!(code, 200);
    assert_eq!(s.req("recovered").unwrap().as_bool(), Some(true));
    assert_eq!(s.req("residual").unwrap().as_f64(), Some(residual_a));
    let mut resubmit_a = spec_a.as_object().unwrap().clone();
    resubmit_a.insert("id".to_string(), Json::num(id_a as f64));
    let (code, s) = client.post("/v1/jobs", Some(&Json::Object(resubmit_a))).unwrap();
    assert_eq!(code, 200, "{s:?}");
    assert_eq!(s.req("recovered").unwrap().as_bool(), Some(true));
    assert!(server.service().job(id_a).is_none(), "A never re-entered the service");

    // SSE works after the restart: follow B through execution.
    let follower = {
        let client = client.clone();
        let path = format!("/v1/jobs/{id_b}/events");
        std::thread::spawn(move || client.follow_events(&path).unwrap())
    };
    server.service().run_pending();
    let events_b = follower.join().unwrap();
    assert_eq!(
        events_b
            .iter()
            .rev()
            .find(|(n, _)| n == "phase")
            .unwrap()
            .1
            .req("status")
            .unwrap()
            .as_str(),
        Some("completed")
    );
    drop(server);

    // Exactly-once: the raw log holds one terminal record per job.
    let text = std::fs::read_to_string(dir.join("jobs.log")).unwrap();
    let terminals = |id: u64| {
        text.lines()
            .filter(|l| l.contains("\"type\":\"terminal\"") && l.contains(&format!("\"id\":{id},")))
            .count()
    };
    assert_eq!(terminals(id_a), 1);
    assert_eq!(terminals(id_b), 1);
    // And a third replay sees nothing pending.
    let (_log, replay) = JobLog::open(&dir).unwrap();
    assert_eq!(replay.pending().count(), 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Kill the spawned server even when an assert panics mid-test.
struct KillOnDrop(Child);

impl Drop for KillOnDrop {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

/// CI smoke: launch the real `spin` binary, parse the printed address,
/// and drive the API from outside the process.
#[test]
fn binary_serve_http_smoke() {
    let dir = tmp_dir("smoke");
    let child = Command::new(env!("CARGO_BIN_EXE_spin"))
        .args([
            "serve",
            "--http",
            "127.0.0.1:0",
            "--workers",
            "2",
            "--store",
            dir.to_str().unwrap(),
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .unwrap();
    let mut child = KillOnDrop(child);
    let stdout = child.0.stdout.take().unwrap();
    let mut lines = BufReader::new(stdout).lines();
    let addr = loop {
        let line = lines
            .next()
            .expect("server exited before printing its address")
            .unwrap();
        if let Some(rest) = line.strip_prefix("listening on http://") {
            break rest.trim().to_string();
        }
    };
    let client = HttpClient::new(addr);

    let (code, h) = client.get("/v1/healthz").unwrap();
    assert_eq!(code, 200);
    assert_eq!(h.req("ok").unwrap().as_bool(), Some(true));

    let spec = Json::parse(&invert_spec_json(32, 8, 11, "smoke")).unwrap();
    let (code, reply) = client.post("/v1/jobs", Some(&spec)).unwrap();
    assert_eq!(code, 202, "{reply:?}");
    let id = reply.req("id").unwrap().as_i64().unwrap();

    // Poll status to terminal (the SSE path is covered in-process).
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let (code, s) = client.get(&format!("/v1/jobs/{id}")).unwrap();
        assert_eq!(code, 200);
        let status = s.req("status").unwrap().as_str().unwrap().to_string();
        if status == "completed" {
            assert!(s.req("residual").unwrap().as_f64().unwrap() < 1e-8);
            break;
        }
        assert!(
            status == "queued" || status == "running",
            "unexpected terminal: {s:?}"
        );
        assert!(Instant::now() < deadline, "job never finished");
        std::thread::sleep(Duration::from_millis(50));
    }

    // Cancel answers 2xx whatever the race outcome; metrics answer.
    let (code, _) = client.post(&format!("/v1/jobs/{id}/cancel"), None).unwrap();
    assert_eq!(code, 200);
    let (code, g) = client.get("/v1/metrics").unwrap();
    assert_eq!(code, 200);
    assert_eq!(g.req("generation").unwrap().as_i64(), Some(1));
    drop(child);
    let _ = std::fs::remove_dir_all(&dir);
}

/// 50 jobs over HTTP across tenants: every one reaches `completed`, the
/// retention counters stay bounded, and the driver never materializes a
/// block at submit.
#[test]
fn http_soak_50_jobs_across_tenants() {
    let service = SpinService::builder()
        .workers(2)
        .queue_capacity(64)
        .build()
        .unwrap();
    let server = bind(service);
    let client = HttpClient::new(server.local_addr().to_string());
    let tenants = ["alice", "bob", "carol", "dave"];
    let mut ids = Vec::new();
    for i in 0..50u64 {
        let spec = Json::parse(&invert_spec_json(
            32,
            8,
            100 + (i % 8),
            tenants[(i % 4) as usize],
        ))
        .unwrap();
        let (code, reply) = client.post("/v1/jobs", Some(&spec)).unwrap();
        assert_eq!(code, 202, "submit {i}: {reply:?}");
        ids.push(reply.req("id").unwrap().as_i64().unwrap() as u64);
    }
    server.service().wait_idle();
    for id in &ids {
        let (code, s) = client.get(&format!("/v1/jobs/{id}")).unwrap();
        assert_eq!(code, 200);
        assert_eq!(s.req("status").unwrap().as_str(), Some("completed"), "{s:?}");
        assert_eq!(s.req("submit_driver_blocks").unwrap().as_i64(), Some(0));
    }
    let (code, g) = client.get("/v1/metrics").unwrap();
    assert_eq!(code, 200);
    // Retention: finished jobs release their stage records; the resident
    // window stays far below 50 jobs' worth of stages.
    let retained = g.req("retained_stage_records").unwrap().as_i64().unwrap();
    let released = g.req("released_stage_records").unwrap().as_i64().unwrap();
    assert!(released > 0, "{g:?}");
    assert!(retained <= released, "retained {retained} vs released {released}");
}
