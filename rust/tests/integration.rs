//! Cross-module integration tests: the full distributed stack (session +
//! cluster + blockmatrix + algos + runtime), both backends, storage
//! round-trips, and the experiment harness glue.
//!
//! XLA-backend tests are gated on `artifacts/manifest.json` (built by
//! `make artifacts`); they are skipped, not failed, without it.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use spin::blockmatrix::{Block, BlockMatrix};
use spin::cluster::Cluster;
use spin::config::{BackendKind, ClusterConfig, GeneratorKind, JobConfig, LeafMethod};
use spin::linalg::{inverse_residual, lu_inverse, matmul, Matrix};
use spin::runtime::{make_backend, BlockKernels, NativeBackend, XlaBackend};
use spin::session::{AlgorithmRegistry, InversionAlgorithm, SpinSession};
use spin::util::check::forall;
use spin::util::Rng;
use spin::Result;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn paper_session() -> SpinSession {
    SpinSession::builder().paper_cluster().build().unwrap()
}

// ---------------- session API over the native backend ----------------

#[test]
fn spin_full_grid_sweep_native() {
    let session = paper_session();
    for (n, bs) in [(16usize, 4usize), (32, 4), (32, 8), (64, 8), (64, 16), (128, 32)] {
        let a = session
            .random_seeded(n, bs, 0x100 + n as u64 + bs as u64)
            .unwrap();
        let inv = a.inverse().unwrap();
        let resid = a.inverse_residual(&inv).unwrap();
        assert!(resid < 1e-9, "spin n={n} bs={bs}: {resid:.3e}");
    }
}

#[test]
fn lu_full_grid_sweep_native() {
    let session = paper_session();
    for (n, bs) in [(16usize, 4usize), (32, 8), (64, 16), (128, 32)] {
        let a = session.random_seeded(n, bs, 0x200 + n as u64).unwrap();
        let inv = a.inverse_with("lu").unwrap();
        let resid = a.inverse_residual(&inv).unwrap();
        assert!(resid < 1e-9, "lu n={n} bs={bs}: {resid:.3e}");
    }
}

/// Resilience acceptance property: with deterministic fault injection
/// enabled (panics, task errors, stragglers — forcing retries and
/// speculative re-execution), both algorithms still produce results
/// **bit-identical** to an entirely fault-free run. Retries re-execute
/// the same pure closure on the same inputs, so recovery must never be
/// observable in the output — only in the resilience counters.
#[test]
fn faulted_run_is_bit_identical_to_clean_run_property() {
    forall(
        "chaos run ≡ clean run, bit for bit",
        0xFA_0175,
        4,
        |r| (r.next_u64(), 1 + r.next_u64() % 0xFFFF),
        |&(matrix_seed, fault_seed)| {
            for algo in ["spin", "lu"] {
                let mut chaos = ClusterConfig::local(4);
                chaos.fault_seed = Some(fault_seed);
                chaos.fault_rate = 0.1;
                // Generous budget: the property must hold for every
                // sampled fault stream, not just streak-free ones.
                chaos.task_retries = 5;
                let faulted_session = SpinSession::builder()
                    .cluster_config(chaos)
                    .build()
                    .unwrap();
                let clean_session = SpinSession::local(4).unwrap();

                let run = |session: &SpinSession| -> std::result::Result<Matrix, String> {
                    let a = session
                        .random_seeded(128, 16, matrix_seed)
                        .map_err(|e| e.to_string())?;
                    let inv = a.inverse_with(algo).map_err(|e| e.to_string())?;
                    let resid = a.inverse_residual(&inv).map_err(|e| e.to_string())?;
                    if resid >= 1e-8 {
                        return Err(format!("{algo} residual {resid:.3e}"));
                    }
                    inv.to_dense().map_err(|e| e.to_string())
                };
                let faulted = run(&faulted_session)?;
                let clean = run(&clean_session)?;

                for (i, (f, c)) in faulted.data().iter().zip(clean.data()).enumerate() {
                    if f.to_bits() != c.to_bits() {
                        return Err(format!(
                            "{algo} seed={matrix_seed:#x} fault_seed={fault_seed}: \
                             element {i} differs: {f:e} vs {c:e}"
                        ));
                    }
                }

                // The chaos run must actually have exercised recovery,
                // and the clean run must be provably untouched by it.
                let faulted_res = *faulted_session.metrics().resilience();
                if faulted_res.retries == 0 {
                    return Err(format!("{algo}: fault injection never fired"));
                }
                if faulted_res.retry_exhausted != 0 {
                    return Err(format!("{algo}: a stage ran out of retries"));
                }
                let clean_res = *clean_session.metrics().resilience();
                if clean_res.retries != 0 || clean_res.speculative_launched != 0 {
                    return Err(format!("{algo}: clean run recorded recovery {clean_res:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn spin_matches_serial_strassen_property() {
    forall(
        "distributed SPIN ≡ serial Algorithm 1",
        0x31,
        6,
        |r| {
            let n = 1usize << (4 + r.next_usize(2)); // 16 or 32
            let bs = 1usize << (2 + r.next_usize(2)); // 4 or 8
            (n, bs.min(n), r.next_u64())
        },
        |&(n, bs, seed)| {
            let session = paper_session();
            let a = session.random_seeded(n, bs, seed).unwrap();
            let dense = a.to_dense().unwrap();
            let dist = a
                .inverse()
                .map_err(|e| e.to_string())?
                .to_dense()
                .unwrap();
            let serial =
                spin::algos::strassen_inverse_serial(&dense, bs).map_err(|e| e.to_string())?;
            let diff = dist.max_abs_diff(&serial);
            if diff < 1e-7 {
                Ok(())
            } else {
                Err(format!("distributed vs serial diff {diff}"))
            }
        },
    );
}

#[test]
fn spd_and_both_leaf_methods() {
    for leaf in [LeafMethod::Lu, LeafMethod::GaussJordan] {
        let session = SpinSession::builder()
            .paper_cluster()
            .generator(GeneratorKind::Spd)
            .leaf(leaf)
            .build()
            .unwrap();
        let a = session.random(64, 16).unwrap();
        let inv = a.inverse().unwrap();
        let resid = a.inverse_residual(&inv).unwrap();
        assert!(resid < 1e-9, "{leaf:?}: {resid:.3e}");
    }
}

#[test]
fn virtual_time_accumulates_and_resets_across_runs() {
    let session = paper_session();
    let a = session.random(32, 8).unwrap();
    // Handles are lazy: building the inverse plan costs nothing until a
    // materialization point.
    let inv1 = a.inverse().unwrap();
    assert_eq!(session.virtual_secs(), 0.0, "plan construction is free");
    inv1.collect().unwrap();
    let t1 = session.virtual_secs();
    assert!(t1 > 0.0);
    // Re-materializing the same handle is memoized (free); a fresh plan
    // accumulates more virtual time.
    inv1.collect().unwrap();
    assert_eq!(session.virtual_secs(), t1, "memoized plan re-read is free");
    a.inverse().unwrap().collect().unwrap();
    assert!(session.virtual_secs() > t1, "clock must accumulate");
    session.reset_clock();
    assert_eq!(session.virtual_secs(), 0.0);
}

// ---------------- partitioner-aware dataflow (acceptance) ----------------

/// The PR's headline claim, measured end to end at the paper-relevant
/// geometry (n = 256, block 32, b = 8): the partitioner-aware pipeline
/// inverts with strictly fewer shuffle bytes and zero driver
/// materializations versus the original replicated/cogroup dataflow
/// (still reachable via `partitioner_aware = false`), at unchanged
/// numerical quality.
#[test]
fn partitioner_aware_spin_cuts_shuffle_and_driver_roundtrips() {
    let mut job = JobConfig::new(256, 32);
    job.seed = 0xACE5;
    let a = BlockMatrix::random(&job).unwrap();
    let dense = a.to_dense().unwrap();

    let run = |aware: bool| {
        let mut cfg = ClusterConfig::paper();
        cfg.partitioner_aware = aware;
        let cluster = Cluster::new(cfg);
        let inv = spin::algos::SpinAlgorithm
            .invert(&cluster, &NativeBackend, &a, &job)
            .unwrap();
        let resid = inverse_residual(&dense, &inv.to_dense().unwrap());
        (cluster.metrics(), resid)
    };
    let (aware, resid_aware) = run(true);
    let (legacy, resid_legacy) = run(false);

    assert!(resid_aware < 1e-8, "aware residual {resid_aware:.3e}");
    assert!(resid_legacy < 1e-8, "legacy residual {resid_legacy:.3e}");
    assert!(
        aware.total_shuffle_bytes() < legacy.total_shuffle_bytes(),
        "shuffle bytes must drop: aware {} vs legacy {}",
        aware.total_shuffle_bytes(),
        legacy.total_shuffle_bytes()
    );
    assert!(
        aware.total_shuffle_stages() < legacy.total_shuffle_stages(),
        "exchange count must drop: aware {} vs legacy {}",
        aware.total_shuffle_stages(),
        legacy.total_shuffle_stages()
    );
    assert_eq!(
        aware.driver_collects(),
        0,
        "partitioner-aware recursion must never round-trip the driver"
    );
    assert!(
        legacy.driver_collects() > 0,
        "legacy path re-parallelizes through the driver"
    );
    // Narrow ops really are narrow: zero shuffle bytes outside multiply.
    for m in ["subtract", "breakMat", "xy", "arrange", "scalar", "leafNode"] {
        if let Some(s) = aware.method(m) {
            assert_eq!(s.shuffle_bytes, 0, "{m} shuffled");
            assert_eq!(s.shuffle_stages, 0, "{m} paid an exchange");
        }
    }
}

/// The optimizer generalizes PR 2's hand fusion: a *composed*
/// multiply+subtract plan now lowers through the same fused
/// `multiply_sub` stage as the explicit method, and only turning the plan
/// optimizer off brings the standalone subtract stage back.
#[test]
fn composed_multiply_subtract_fuses_via_optimizer() {
    let session_fused = paper_session();
    let mut unfused_cfg = ClusterConfig::paper();
    unfused_cfg.plan_optimizer = false;
    let session_raw = SpinSession::builder()
        .cluster_config(unfused_cfg)
        .build()
        .unwrap();
    fn mk(
        s: &SpinSession,
    ) -> (
        spin::session::DistMatrix<'_>,
        spin::session::DistMatrix<'_>,
        spin::session::DistMatrix<'_>,
    ) {
        (
            s.random_seeded(64, 16, 0x601).unwrap(),
            s.random_seeded(64, 16, 0x602).unwrap(),
            s.random_seeded(64, 16, 0x603).unwrap(),
        )
    }
    // Composed ops on the optimizing session: fused like multiply_sub.
    let (a, b, d) = mk(&session_fused);
    let fused = a
        .multiply(&b)
        .unwrap()
        .subtract(&d)
        .unwrap()
        .to_dense()
        .unwrap();
    // Same composition with the optimizer off: the subtract stage runs.
    let (a2, b2, d2) = mk(&session_raw);
    let composed = a2
        .multiply(&b2)
        .unwrap()
        .subtract(&d2)
        .unwrap()
        .to_dense()
        .unwrap();
    assert_eq!(fused.max_abs_diff(&composed), 0.0, "fusion is bit-exact");

    let sf = session_fused.metrics();
    let sc = session_raw.metrics();
    assert!(sf.method("subtract").is_none(), "subtract folded into multiply");
    assert!(sc.method("subtract").is_some(), "unfused plan keeps subtract");
    assert!(
        sf.stages().len() < sc.stages().len(),
        "fused {} stages vs composed {}",
        sf.stages().len(),
        sc.stages().len()
    );
    assert!(sf.total_shuffle_bytes() <= sc.total_shuffle_bytes());
    assert!(sf.plan_nodes().iter().any(|p| p.op == "multiply_sub"));
}

// ---------------- new workloads: solve and pseudo-inverse ----------------

#[test]
fn session_solve_matches_serial_reference() {
    let session = paper_session();
    let a = session.random_seeded(64, 16, 0x501).unwrap();
    let b = session.random_seeded(64, 16, 0x502).unwrap();
    let x = a.solve(&b).unwrap();
    let want = matmul(
        &lu_inverse(&a.to_dense().unwrap()).unwrap(),
        &b.to_dense().unwrap(),
    );
    let diff = x.to_dense().unwrap().max_abs_diff(&want);
    assert!(diff < 1e-8, "solve vs serial reference diff {diff}");
    // Residual form: ‖A·X − B‖∞ relative to ‖B‖∞.
    let ax = a.multiply(&x).unwrap().to_dense().unwrap();
    let bd = b.to_dense().unwrap();
    let resid = ax.max_abs_diff(&bd) / bd.max_abs();
    assert!(resid < 1e-9, "solve residual {resid:.3e}");
}

#[test]
fn session_solve_dense_and_solve_with_lu() {
    let session = paper_session();
    let a = session.random_seeded(32, 8, 0x511).unwrap();
    // Rectangular dense RHS (n×2).
    let mut rng = Rng::new(0x512);
    let rhs = Matrix::random_uniform(32, 2, -1.0, 1.0, &mut rng);
    let x = a.solve_dense(&rhs).unwrap();
    let resid = matmul(&a.to_dense().unwrap(), &x).max_abs_diff(&rhs);
    assert!(resid < 1e-9, "solve_dense residual {resid:.3e}");
    // solve_with("lu") agrees with the default (spin) path.
    let b = session.random_seeded(32, 8, 0x513).unwrap();
    let via_spin = a.solve(&b).unwrap().to_dense().unwrap();
    let via_lu = a.solve_with("lu", &b).unwrap().to_dense().unwrap();
    assert!(via_spin.max_abs_diff(&via_lu) < 1e-8);
}

#[test]
fn session_pseudo_inverse_matches_serial_inverse() {
    let session = paper_session();
    let m = session.random_spd(64, 16).unwrap();
    let pinv = m.pseudo_inverse().unwrap();
    // Full-rank square input: M⁺ = M⁻¹ (serial LU reference).
    let want = lu_inverse(&m.to_dense().unwrap()).unwrap();
    let diff = pinv.to_dense().unwrap().max_abs_diff(&want);
    assert!(diff < 1e-6, "pseudo-inverse vs serial inverse diff {diff}");
    let resid = m.inverse_residual(&pinv).unwrap();
    assert!(resid < 1e-8, "pseudo-inverse residual {resid:.3e}");
}

// ---------------- registry behavior ----------------

#[test]
fn registry_rejects_duplicates_and_unknowns() {
    let mut registry = AlgorithmRegistry::with_defaults();
    assert_eq!(registry.names(), vec!["lu".to_string(), "spin".to_string()]);

    struct FakeSpin;
    impl InversionAlgorithm for FakeSpin {
        fn name(&self) -> &str {
            "spin"
        }
        fn invert(
            &self,
            _cluster: &Cluster,
            _kernels: &dyn BlockKernels,
            _a: &BlockMatrix,
            _job: &JobConfig,
        ) -> Result<BlockMatrix> {
            unreachable!("duplicate registration must be rejected")
        }
    }
    let err = registry.register(Arc::new(FakeSpin)).unwrap_err();
    assert!(err.to_string().contains("already registered"), "{err}");

    let err = registry.get("qr").unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("unknown algorithm `qr`"), "{msg}");
    assert!(msg.contains("lu|spin"), "{msg}");
}

#[test]
fn externally_registered_algorithm_reachable_by_name() {
    // A user-provided scheme: scale by 2, invert with SPIN, scale by 2 —
    // 2·(2A)⁻¹ == A⁻¹ — exercised purely through the public API.
    struct ScaledSpin;
    impl InversionAlgorithm for ScaledSpin {
        fn name(&self) -> &str {
            "scaled-spin"
        }
        fn invert(
            &self,
            cluster: &Cluster,
            kernels: &dyn BlockKernels,
            a: &BlockMatrix,
            job: &JobConfig,
        ) -> Result<BlockMatrix> {
            let doubled = a.scalar_mul(cluster, kernels, 2.0)?;
            let inv = spin::algos::SpinAlgorithm.invert(cluster, kernels, &doubled, job)?;
            inv.scalar_mul(cluster, kernels, 2.0)
        }
    }
    let session = SpinSession::builder()
        .cores(4)
        .register_algorithm(Arc::new(ScaledSpin))
        .unwrap()
        .build()
        .unwrap();
    let a = session.random(32, 8).unwrap();
    let inv = a.inverse_with("scaled-spin").unwrap();
    let resid = a.inverse_residual(&inv).unwrap();
    assert!(resid < 1e-10, "scaled-spin residual {resid:.3e}");
}

// ---------------- BlockMatrix::from_blocks error paths ----------------

#[test]
fn from_blocks_error_paths_via_session() {
    let session = SpinSession::local(2).unwrap();
    // Duplicate index.
    let dup = vec![
        Block::new(0, 0, Matrix::zeros(4, 4)),
        Block::new(0, 0, Matrix::zeros(4, 4)),
        Block::new(1, 0, Matrix::zeros(4, 4)),
        Block::new(1, 1, Matrix::zeros(4, 4)),
    ];
    let err = session.from_blocks(dup, 2, 4).unwrap_err();
    assert!(err.to_string().contains("duplicate block index"), "{err}");
    // Wrong-size block.
    let bad_size = vec![
        Block::new(0, 0, Matrix::zeros(3, 4)),
        Block::new(0, 1, Matrix::zeros(4, 4)),
        Block::new(1, 0, Matrix::zeros(4, 4)),
        Block::new(1, 1, Matrix::zeros(4, 4)),
    ];
    let err = session.from_blocks(bad_size, 2, 4).unwrap_err();
    assert!(err.to_string().contains("expected 4x4"), "{err}");
    // Out-of-grid index.
    let oob = vec![Block::new(2, 0, Matrix::zeros(4, 4))];
    assert!(session.from_blocks(oob, 1, 4).is_err());
    // Wrong count.
    assert!(session.from_blocks(vec![], 1, 4).is_err());
}

// ---------------- lazy-plan acceptance (this PR's headline) ----------

/// The plan-driven SPIN pipeline must be *bit-identical* to PR 2's eager
/// fused pipeline (reconstructed here with direct `BlockMatrix` ops), at
/// the acceptance geometry n = 256 / block 32, with shuffle-stage and
/// driver-collect counts no worse — the optimizer's fusion replaces the
/// hand-wired `multiply_sub`, it does not merely approximate it.
#[test]
fn plan_driven_spin_matches_eager_pipeline_bit_for_bit() {
    let mut job = JobConfig::new(256, 32);
    job.seed = 0xACE5;
    let a = BlockMatrix::random(&job).unwrap();
    let dense = a.to_dense().unwrap();

    // PR 2's eager pipeline: hand-ordered ops with hand-fused Schur step.
    fn eager_rec(cluster: &Cluster, a: &BlockMatrix, job: &JobConfig) -> BlockMatrix {
        if a.nblocks() == 1 {
            return a
                .map_blocks_try(cluster, "leafNode", |m| {
                    NativeBackend.leaf_inverse(m, job.leaf)
                })
                .unwrap();
        }
        let (a11, a12, a21, a22) = a.split(cluster).unwrap();
        let i = eager_rec(cluster, &a11, job);
        let ii = a21.multiply(cluster, &NativeBackend, &i).unwrap();
        let iii = i.multiply(cluster, &NativeBackend, &a12).unwrap();
        let v = a21
            .multiply_sub(cluster, &NativeBackend, &iii, &a22)
            .unwrap();
        let vi = eager_rec(cluster, &v, job);
        let c12 = iii.multiply(cluster, &NativeBackend, &vi).unwrap();
        let c21 = vi.multiply(cluster, &NativeBackend, &ii).unwrap();
        let vii = iii.multiply(cluster, &NativeBackend, &c21).unwrap();
        let c11 = i.subtract(cluster, &NativeBackend, &vii).unwrap();
        let c22 = vi.scalar_mul(cluster, &NativeBackend, -1.0).unwrap();
        BlockMatrix::arrange(cluster, c11, c12, c21, c22).unwrap()
    }

    let c_eager = Cluster::new(ClusterConfig::paper());
    let eager = eager_rec(&c_eager, &a, &job);

    let c_plan = Cluster::new(ClusterConfig::paper());
    let plan = spin::algos::SpinAlgorithm
        .invert(&c_plan, &NativeBackend, &a, &job)
        .unwrap();

    let plan_dense = plan.to_dense().unwrap();
    assert_eq!(
        plan_dense.max_abs_diff(&eager.to_dense().unwrap()),
        0.0,
        "plan-driven SPIN must be bit-identical to the eager pipeline"
    );
    let resid = inverse_residual(&dense, &plan_dense);
    assert!(resid < 1e-8, "residual {resid:.3e}");

    let me = c_eager.metrics();
    let mp = c_plan.metrics();
    assert!(
        mp.total_shuffle_stages() <= me.total_shuffle_stages(),
        "plan path must not add exchanges: {} vs {}",
        mp.total_shuffle_stages(),
        me.total_shuffle_stages()
    );
    assert!(
        mp.stages().len() <= me.stages().len(),
        "plan path must not add stages: {} vs {}",
        mp.stages().len(),
        me.stages().len()
    );
    assert_eq!(mp.driver_collects(), 0, "plans never round-trip the driver");
    // Per-plan-node metrics were stamped, with the optimizer-derived
    // fusion and at least one CSE cache point per level.
    assert!(mp.plan_nodes().iter().any(|p| p.op == "multiply_sub"));
    assert!(mp.plan_nodes().iter().any(|p| p.cse_cached));
}

/// `explain` on the session surfaces the fusion and the CSE cache nodes
/// the acceptance criteria name.
#[test]
fn session_explain_shows_fusion_and_cache_nodes() {
    let session = paper_session();
    let text = session.explain_invert("spin", 256, 32).unwrap();
    assert!(text.contains("multiply_sub"), "{text}");
    assert!(text.contains("cache("), "{text}");
}

// ---------------- SpinService: multi-tenant jobs (this PR's headline) ----

/// Acceptance: two concurrent service jobs sharing a source matrix are
/// bit-identical to sequential `SpinSession` runs, and the shared
/// subexpression (`invert[spin](A)`) materializes exactly once — proven
/// by stage counts on the shared cluster.
#[test]
fn service_concurrent_jobs_share_work_and_match_sequential() {
    use spin::service::{JobSpec, MatrixSpec, SpinService};

    // Sequential reference on a plain session: one inversion feeds both
    // the inverse read-out and the solve (shared handle → runs once).
    let session = SpinSession::builder().cores(4).build().unwrap();
    let a = session.random_seeded(64, 16, 0xCAFE).unwrap();
    let b = session.random_seeded(64, 16, 0xBEEF).unwrap();
    let inv = a.inverse_with("spin").unwrap();
    let seq_inv = inv.to_dense().unwrap();
    let seq_solve = inv.multiply(&b).unwrap().to_dense().unwrap();
    let seq_leaves = session.metrics().method("leafNode").unwrap().calls;

    // The service runs the same two workloads concurrently (2 workers)
    // for two tenants, sharing the interned invert node.
    let service = SpinService::builder().cores(4).workers(2).build().unwrap();
    let spec_a = MatrixSpec::new(64, 16).seeded(0xCAFE);
    let spec_b = MatrixSpec::new(64, 16).seeded(0xBEEF);
    let h_inv = service
        .submit(JobSpec::invert(spec_a.clone()).tenant("alice"))
        .unwrap();
    let h_solve = service
        .submit(JobSpec::solve(spec_a, spec_b).tenant("bob"))
        .unwrap();
    let out_inv = h_inv.wait().unwrap();
    let out_solve = h_solve.wait().unwrap();

    assert_eq!(
        out_inv.dense.max_abs_diff(&seq_inv),
        0.0,
        "service inversion must be bit-identical to the session run"
    );
    assert_eq!(
        out_solve.dense.max_abs_diff(&seq_solve),
        0.0,
        "service solve must be bit-identical to the session run"
    );
    assert!(out_inv.residual.unwrap() < 1e-9);

    // Exactly-once sharing: across BOTH jobs the recursion's leaves ran
    // once (grid 4 → 4 leaf inversions), same as the sequential session.
    let total = service.metrics();
    assert_eq!(total.method("leafNode").unwrap().calls, seq_leaves);
    assert_eq!(total.driver_collects(), 0);
    // Whichever job won the race carries the leaf stages; together they
    // account for exactly one inversion.
    let leaves = |m: &spin::cluster::MetricsSnapshot| {
        m.method("leafNode").map(|s| s.calls).unwrap_or(0)
    };
    assert_eq!(leaves(&out_inv.metrics) + leaves(&out_solve.metrics), seq_leaves);
    // The plan cache observed the share.
    assert!(service.plan_cache_stats().hits >= 2);
}

/// Acceptance: an LRU budget of HALF the working set still completes
/// correctly (bit-identical to an unbudgeted session) with eviction
/// counters > 0.
#[test]
fn service_lru_half_budget_completes_with_evictions() {
    use spin::service::{JobSpec, MatrixSpec, SpinService};

    // Unbudgeted reference.
    let session = SpinSession::builder().cores(4).build().unwrap();
    let m_ref = session.random_spd(128, 16).unwrap();
    let want = m_ref.pseudo_inverse().unwrap().to_dense().unwrap();

    // Working set: the pseudo-inverse pipeline holds 4 intermediates of
    // 128×128 doubles (plus the concurrent invert job's value) — budget
    // half of the 4-value set.
    let value_bytes = 128 * 128 * 8;
    let mut cfg = ClusterConfig::local(4);
    cfg.cache_budget_bytes = (2 * value_bytes) as u64;
    let service = SpinService::builder()
        .cluster_config(cfg)
        .workers(2)
        .build()
        .unwrap();
    let spd = MatrixSpec::new(128, 16).spd();
    let h1 = service
        .submit(JobSpec::pseudo_inverse(spd.clone()).tenant("a"))
        .unwrap();
    let h2 = service.submit(JobSpec::invert(spd).tenant("b")).unwrap();
    let o1 = h1.wait().unwrap();
    let o2 = h2.wait().unwrap();
    assert_eq!(
        o1.dense.max_abs_diff(&want),
        0.0,
        "budgeted run must be bit-identical to the unbudgeted session"
    );
    assert!(o2.residual.unwrap() < 1e-8);
    assert!(
        service.metrics().cache_evictions() > 0,
        "half-working-set budget must evict"
    );
    let stats = service.cache_stats();
    assert!(stats.evictions > 0);
    assert!(stats.resident_bytes <= (2 * value_bytes) as u64);
}

/// Regression (metrics accounting): two jobs executing simultaneously on
/// one cluster must not double-count each other's stage windows — each
/// job's multiply plan-node reports exactly its own single shuffle round.
#[test]
fn concurrent_jobs_do_not_double_count_plan_windows() {
    use spin::service::{JobSpec, MatrixSpec, SpinService};
    let service = SpinService::builder().cores(4).workers(2).build().unwrap();
    let mul = |s1: u64, s2: u64, tenant: &str| {
        JobSpec::multiply(
            MatrixSpec::new(64, 16).seeded(s1),
            MatrixSpec::new(64, 16).seeded(s2),
        )
        .tenant(tenant)
    };
    let h1 = service.submit(mul(1, 2, "alice")).unwrap();
    let h2 = service.submit(mul(3, 4, "bob")).unwrap();
    let m1 = h1.wait().unwrap().metrics;
    let m2 = h2.wait().unwrap().metrics;
    for m in [&m1, &m2] {
        assert_eq!(m.method("multiply").unwrap().shuffle_stages, 2);
        let node = m
            .plan_nodes()
            .iter()
            .find(|p| p.op == "multiply")
            .expect("each job stamped its multiply window");
        assert_eq!(
            node.shuffle_stages, 2,
            "plan-node window absorbed another job's exchanges"
        );
        assert_eq!(node.driver_collects, 0);
    }
    assert_eq!(service.metrics().total_shuffle_stages(), 4);
}

/// Regression (deterministic form): two plans forced to interleave on
/// one cluster under explicit metric scopes — per-scope windows stay
/// exact no matter how the stage streams interleave.
#[test]
fn interleaved_plan_windows_stay_exact_under_explicit_scopes() {
    use spin::cluster::Metrics;
    use spin::plan::{MatExpr, PlanExec};

    let cluster = Cluster::new(ClusterConfig::local(4));
    let src = |seed: u64| {
        let mut job = JobConfig::new(64, 16);
        job.seed = seed;
        MatExpr::source(BlockMatrix::random(&job).unwrap())
    };
    let e1 = src(11).multiply(&src(12)).unwrap();
    let e2 = src(13).multiply(&src(14)).unwrap();
    let exec = PlanExec::new(&cluster, &NativeBackend);
    let barrier = std::sync::Barrier::new(2);
    std::thread::scope(|scope| {
        scope.spawn(|| {
            let _tag = Metrics::enter_scope(101);
            barrier.wait();
            exec.eval(&e1).unwrap();
        });
        scope.spawn(|| {
            let _tag = Metrics::enter_scope(102);
            barrier.wait();
            exec.eval(&e2).unwrap();
        });
    });
    for scope in [101u64, 102] {
        let snap = cluster.metrics_scoped(scope);
        assert_eq!(snap.method("multiply").unwrap().shuffle_stages, 2);
        for node in snap.plan_nodes() {
            if node.op == "multiply" {
                assert_eq!(node.shuffle_stages, 2, "scope {scope} window leaked");
            }
        }
    }
    // Global view sees both jobs.
    assert_eq!(cluster.metrics().total_shuffle_stages(), 4);
}

/// The service integration surface under the CI thread matrix: with
/// `SPIN_WORKER_THREADS=4` the cluster's real worker pool and the
/// service's job threads are both multi-threaded at once.
#[test]
fn service_with_multithreaded_worker_pool() {
    use spin::service::{JobSpec, MatrixSpec, SpinService};
    let mut cfg = ClusterConfig::local(4);
    cfg.worker_threads = 4;
    let service = SpinService::builder()
        .cluster_config(cfg)
        .workers(2)
        .build()
        .unwrap();
    let handles: Vec<_> = (0..4)
        .map(|i| {
            service
                .submit(
                    JobSpec::invert(MatrixSpec::new(64, 16).seeded(0x700 + i))
                        .tenant(if i % 2 == 0 { "even" } else { "odd" }),
                )
                .unwrap()
        })
        .collect();
    for h in handles {
        let out = h.wait().unwrap();
        assert!(out.residual.unwrap() < 1e-9);
    }
}

// ---------------- lazy sources + bounded metrics (PR 5 headline) --------

/// Acceptance (property): for every generator family, geometry and seed,
/// the lazy worker-generated leaf is BIT-identical to the eager
/// driver-generated matrix — the per-block RNG streams make generation a
/// pure per-block function, so where blocks are born cannot matter.
#[test]
fn lazy_and_eager_generation_bit_identical_property() {
    forall(
        "lazy ≡ eager generation",
        0x1A27,
        8,
        |r| {
            let n = 16 << r.next_usize(3); // 16 | 32 | 64
            let bs = n / (2 << r.next_usize(2)); // grids 2, 4 or 8
            let generator = if r.next_f64() < 0.5 {
                GeneratorKind::DiagDominant
            } else {
                GeneratorKind::Spd
            };
            (n, bs, generator, r.next_u64() >> 12)
        },
        |&(n, bs, generator, seed)| {
            let session = SpinSession::builder()
                .cores(2)
                .generator(generator)
                .build()
                .map_err(|e| e.to_string())?;
            let lazy = session
                .lazy_random_seeded(n, bs, seed)
                .map_err(|e| e.to_string())?
                .to_dense()
                .map_err(|e| e.to_string())?;
            let eager = session
                .random_seeded(n, bs, seed)
                .map_err(|e| e.to_string())?
                .to_dense()
                .map_err(|e| e.to_string())?;
            if lazy.max_abs_diff(&eager) == 0.0 {
                Ok(())
            } else {
                Err(format!("{generator:?} n={n} bs={bs} seed={seed} diverged"))
            }
        },
    );
}

/// Acceptance (iterative subsystem, satellite): a lazy `spd` source under
/// the `cholesky` scheme is bit-identical to its eager twin, and stays so
/// after the LRU evictor drops intermediates — eviction means bit-exact
/// recomputation, for iterative-subsystem values like any other.
#[test]
fn cholesky_lazy_spd_matches_eager_and_survives_eviction() {
    let mut cfg = ClusterConfig::local(4);
    // Budget = one 64×64 value: the source + inverse cannot both stay
    // resident, so re-reads exercise the evict → regenerate path.
    cfg.cache_budget_bytes = 64 * 64 * 8;
    let session = SpinSession::builder()
        .cluster_config(cfg)
        .generator(GeneratorKind::Spd)
        .build()
        .unwrap();
    let lazy = session.lazy_random_seeded(64, 16, 0xC0DE).unwrap();
    let eager = session.random_seeded(64, 16, 0xC0DE).unwrap();
    assert_eq!(
        lazy.to_dense()
            .unwrap()
            .max_abs_diff(&eager.to_dense().unwrap()),
        0.0,
        "lazy and eager spd generation share one per-block function"
    );
    let inv_lazy = lazy.inverse_with("cholesky").unwrap();
    let inv_eager = eager.inverse_with("cholesky").unwrap();
    let first = inv_lazy.to_dense().unwrap();
    assert_eq!(
        first.max_abs_diff(&inv_eager.to_dense().unwrap()),
        0.0,
        "cholesky over a lazy source must equal the eager pipeline"
    );
    assert!(lazy.inverse_residual(&inv_lazy).unwrap() < 1e-10);
    assert!(
        session.metrics().cache_evictions() > 0,
        "one-value budget must evict"
    );
    // Whatever the evictor dropped recomputes to the same bits.
    let again = inv_lazy.to_dense().unwrap();
    assert_eq!(first.max_abs_diff(&again), 0.0);
}

/// Acceptance (iterative subsystem, satellite): `newton` and `cholesky`
/// are bit-identical at any executor width, and newton's convergence
/// trajectory (iteration count) is executor-independent too — the
/// driver-side loop reads the same residuals whichever lanes computed
/// the blocks.
#[test]
fn iterative_schemes_bit_identical_across_exec_threads() {
    let run = |threads: usize, algo: &str, generator: GeneratorKind| -> (Matrix, usize) {
        let mut cfg = ClusterConfig::local(4);
        cfg.exec_threads = threads;
        let session = SpinSession::builder()
            .cluster_config(cfg)
            .generator(generator)
            .build()
            .unwrap();
        let a = session.random_seeded(64, 16, 0xBEEF).unwrap();
        let inv = a.inverse_with(algo).unwrap();
        let dense = inv.to_dense().unwrap();
        let iters = session
            .metrics()
            .convergence()
            .iter()
            .map(|r| r.iterations)
            .sum();
        (dense, iters)
    };
    for (algo, generator) in [
        ("newton", GeneratorKind::DiagDominant),
        ("cholesky", GeneratorKind::Spd),
    ] {
        let (seq, seq_iters) = run(1, algo, generator);
        let (par, par_iters) = run(4, algo, generator);
        for (i, (s, p)) in seq.data().iter().zip(par.data()).enumerate() {
            assert_eq!(
                s.to_bits(),
                p.to_bits(),
                "{algo}: element {i} differs between 1 and 4 exec lanes"
            );
        }
        assert_eq!(
            seq_iters, par_iters,
            "{algo}: iteration counts must not depend on executor width"
        );
        if algo == "newton" {
            assert!(seq_iters >= 1, "newton must record its trajectory");
        }
    }
}

/// Acceptance (store round-trip): ingest a generated matrix into a block
/// store, serve it through `MatrixSpec::from_store`, invert, and check
/// the residual — the full write → lazy-load → compute loop.
#[test]
fn store_round_trip_ingest_serve_invert() {
    use spin::service::{JobSpec, MatrixSpec, SpinService};
    let dir = std::env::temp_dir().join(format!("spin_it_ingest_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut job = JobConfig::new(64, 16);
    job.seed = 0x57;
    job.generator = GeneratorKind::Spd;
    let store = spin::store::LocalDirStore::create(&dir, job.num_splits(), job.block_size).unwrap();
    spin::store::ingest_generated(&store, &job).unwrap();

    let service = SpinService::builder().cores(4).workers(1).build().unwrap();
    let spec = MatrixSpec::from_store(&dir).unwrap();
    let handle = service.submit(JobSpec::invert(spec)).unwrap();
    let out = handle.wait().unwrap();
    assert!(out.residual.unwrap() < 1e-8, "residual {:?}", out.residual);
    assert!(out.metrics.method("loadBlock").is_some());
    assert_eq!(out.metrics.driver_collects(), 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Acceptance (soak): a 50-job serve run on 4 workers releases every
/// completed job's stage records — retained metrics memory is
/// steady-state, asserted via the retention counters, while the
/// lifetime aggregates still account for all 50 jobs.
#[test]
fn soak_serve_releases_completed_job_records() {
    use spin::service::{JobSpec, MatrixSpec, SpinService};
    const JOBS: u64 = 50;
    let service = SpinService::builder()
        .cores(4)
        .workers(4)
        .queue_capacity(JOBS as usize)
        .build()
        .unwrap();
    let mut handles = Vec::new();
    let mut mid_retained = 0usize;
    for i in 0..JOBS {
        // Distinct seeds: every job materializes fresh leaves and plan
        // nodes, the worst case for metrics (and value) retention.
        let spec = MatrixSpec::new(32, 8).seeded(0x5000 + i);
        let job = match i % 3 {
            0 => JobSpec::invert(spec),
            1 => JobSpec::multiply(spec, MatrixSpec::new(32, 8).seeded(0x6000 + i)),
            _ => JobSpec::invert(spec).algorithm("lu"),
        };
        handles.push(service.submit(job.tenant(["a", "b", "c"][i as usize % 3])).unwrap());
        if i == JOBS / 2 {
            mid_retained = service.metrics().retained_stage_records();
        }
    }
    let mut completed = 0;
    for h in &handles {
        let out = h.wait().unwrap();
        // Seeds are distinct, so every job did real work under its scope
        // and the outcome snapshot (taken before release) carries it.
        assert!(!out.metrics.stages().is_empty());
        completed += 1;
    }
    assert_eq!(completed, JOBS);
    let m = service.metrics();
    // Every finished scope was released: nothing job-scoped is retained.
    assert_eq!(m.released_scopes() as u64, JOBS);
    assert_eq!(
        m.retained_stage_records(),
        0,
        "steady state: all work ran under released job scopes \
         (mid-run the backlog held {mid_retained} records)"
    );
    assert!(m.released_stage_records() >= JOBS as usize);
    assert_eq!(m.stages().len(), m.retained_stage_records());
    // Lifetime aggregates survive for the Table-3 view.
    assert!(m.method("generate").unwrap().calls >= 1);
    assert!(m.totals().stages > 0);
}

/// The `metrics_history` window bounds retained records even for work
/// recorded OUTSIDE job scopes (ambient session use on the same cluster).
#[test]
fn metrics_history_window_bounds_ambient_records() {
    let mut cfg = ClusterConfig::local(2);
    cfg.metrics_history = 10;
    let session = SpinSession::builder().cluster_config(cfg).build().unwrap();
    for seed in 0..6 {
        let a = session.random_seeded(16, 4, seed).unwrap();
        let b = session.random_seeded(16, 4, seed + 100).unwrap();
        a.multiply(&b).unwrap().collect().unwrap();
    }
    let m = session.metrics();
    assert!(m.retained_stage_records() <= 10, "window respected");
    assert!(m.released_stage_records() > 0, "old records were dropped");
    assert!(
        m.method("multiply").unwrap().calls >= 6,
        "aggregates still count everything"
    );
}

// ---------------- storage / backend plumbing (unchanged paths) ----------

#[test]
fn block_store_round_trip_via_cli_layer() {
    let dir = std::env::temp_dir().join(format!("spin_it_store_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let code = spin::cli::run(
        format!("gen --n 32 --block-size 8 --seed 5 --out {}", dir.display())
            .split_whitespace()
            .map(String::from)
            .collect(),
    );
    assert_eq!(code, 0);
    let meta = spin::ser::bin::read_block_store_meta(&dir).unwrap();
    assert_eq!(meta.nblocks, 4);
    assert_eq!(meta.block_size, 8);
    // Reassemble and compare against the same-seed generator output.
    let mut dense = Matrix::zeros(32, 32);
    for i in 0..4 {
        for j in 0..4 {
            let blk = spin::ser::bin::read_block(&dir, i, j).unwrap();
            dense.set_submatrix(i * 8, j * 8, &blk).unwrap();
        }
    }
    let mut job = JobConfig::new(32, 8);
    job.seed = 5;
    let want = BlockMatrix::random(&job).unwrap().to_dense().unwrap();
    assert_eq!(dense.max_abs_diff(&want), 0.0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn make_backend_dispatches() {
    let mut cfg = ClusterConfig::paper();
    cfg.backend = BackendKind::Native;
    assert_eq!(make_backend(&cfg).unwrap().name(), "native");
    cfg.backend = BackendKind::Xla;
    cfg.artifacts_dir = PathBuf::from("/definitely/missing");
    assert!(make_backend(&cfg).is_err());
}

#[test]
fn xla_session_fails_fast_without_artifacts() {
    let err = SpinSession::builder()
        .cores(2)
        .backend(BackendKind::Xla)
        .artifacts_dir("/definitely/missing")
        .build()
        .unwrap_err();
    assert!(!err.to_string().is_empty());
}

// ---------------- XLA-backend integration (gated on artifacts) ----------

#[test]
fn spin_distributed_on_xla_backend() {
    let Some(dir) = artifacts_dir() else { return };
    let be = XlaBackend::new(dir).unwrap();
    let cluster = Cluster::new(ClusterConfig::paper());
    let mut job = JobConfig::new(128, 32);
    job.leaf = LeafMethod::GaussJordan;
    let a = BlockMatrix::random(&job).unwrap();
    let inv = spin::algos::SpinAlgorithm
        .invert(&cluster, &be, &a, &job)
        .unwrap();
    let resid = inverse_residual(&a.to_dense().unwrap(), &inv.to_dense().unwrap());
    assert!(resid < 1e-9, "xla spin residual {resid:.3e}");
    assert!(be.executed_count() > 0, "PJRT path must actually execute");
    assert_eq!(be.fallback_count(), 0, "no native fallbacks expected");
}

#[test]
fn lu_distributed_on_xla_backend_is_fully_pjrt() {
    let Some(dir) = artifacts_dir() else { return };
    let be = XlaBackend::new(dir).unwrap();
    let cluster = Cluster::new(ClusterConfig::paper());
    let job = JobConfig::new(64, 16);
    let a = BlockMatrix::random(&job).unwrap();
    let inv = spin::algos::LuAlgorithm
        .invert(&cluster, &be, &a, &job)
        .unwrap();
    let resid = inverse_residual(&a.to_dense().unwrap(), &inv.to_dense().unwrap());
    assert!(resid < 1e-9, "xla lu residual {resid:.3e}");
    // Baseline leaves (lu_factor / invert_lower / invert_upper) must also
    // run through PJRT — fairness of the SPIN-vs-LU comparison.
    assert_eq!(be.fallback_count(), 0, "LU leaves must not fall back");
}

#[test]
fn fused_leaf_2x2_on_xla_matches_unfused() {
    let Some(dir) = artifacts_dir() else { return };
    let build = |fuse: bool| {
        SpinSession::builder()
            .paper_cluster()
            .backend(BackendKind::Xla)
            .artifacts_dir(dir.clone())
            .leaf(LeafMethod::GaussJordan)
            .fuse_leaf_2x2(fuse)
            .build()
            .unwrap()
    };
    let plain_session = build(false);
    let fused_session = build(true);
    let a_plain = plain_session.random(64, 32).unwrap();
    let a_fused = fused_session.random(64, 32).unwrap();
    let plain = a_plain.inverse().unwrap();
    let fused = a_fused.inverse().unwrap();
    let diff = plain
        .to_dense()
        .unwrap()
        .max_abs_diff(&fused.to_dense().unwrap());
    assert!(diff < 1e-8, "fused vs plain diff {diff}");
    // The fused path collapses that level's stages into one task.
    let plain_stages = plain_session.metrics().stages().len();
    let fused_stages = fused_session.metrics().stages().len();
    assert!(
        fused_stages < plain_stages,
        "fusion should reduce stage count: {fused_stages} vs {plain_stages}"
    );
}

#[test]
fn xla_and_native_agree_numerically() {
    let Some(dir) = artifacts_dir() else { return };
    let be = XlaBackend::new(dir).unwrap();
    let c1 = Cluster::new(ClusterConfig::paper());
    let mut job = JobConfig::new(64, 16);
    job.leaf = LeafMethod::GaussJordan;
    let a = BlockMatrix::random(&job).unwrap();
    let x = spin::algos::SpinAlgorithm
        .invert(&c1, &be, &a, &job)
        .unwrap()
        .to_dense()
        .unwrap();
    let session = SpinSession::builder()
        .paper_cluster()
        .leaf(LeafMethod::GaussJordan)
        .build()
        .unwrap();
    let n = session
        .wrap(a)
        .inverse()
        .unwrap()
        .to_dense()
        .unwrap();
    let diff = x.max_abs_diff(&n);
    assert!(diff < 1e-8, "xla vs native diff {diff}");
}

#[test]
fn experiment_harness_runs_on_xla() {
    let Some(_dir) = artifacts_dir() else { return };
    let mut cfg = ClusterConfig::paper();
    cfg.backend = BackendKind::Xla;
    let mut job = JobConfig::new(64, 16);
    job.leaf = LeafMethod::GaussJordan;
    let r = spin::experiments::run_inversion(&cfg, &job, "spin").unwrap();
    assert!(r.residual < 1e-9);
    assert!(r.virtual_secs > 0.0);
}

#[test]
fn multithreaded_workers_with_xla_thread_local_engines() {
    let Some(dir) = artifacts_dir() else { return };
    let mut cfg = ClusterConfig::paper();
    cfg.backend = BackendKind::Xla;
    cfg.artifacts_dir = dir;
    cfg.worker_threads = 3; // forces engines on several threads
    let session = SpinSession::builder()
        .cluster_config(cfg)
        .leaf(LeafMethod::GaussJordan)
        .build()
        .unwrap();
    let a = session.random(64, 16).unwrap();
    let inv = a.inverse().unwrap();
    let resid = a.inverse_residual(&inv).unwrap();
    assert!(resid < 1e-9, "mt xla residual {resid:.3e}");
}

// ---------------- experiment harness / determinism ----------------

#[test]
fn figure5_replay_is_monotone() {
    let cluster = ClusterConfig::paper();
    let mut scale = spin::experiments::Scale::smoke();
    scale.sizes = vec![128];
    let rows = spin::experiments::figure5::run(&cluster, &scale, 9).unwrap();
    spin::experiments::figure5::check_shape(&rows).unwrap();
}

#[test]
fn seeded_rerun_is_bitwise_identical() {
    let session = paper_session();
    let a = session.random(32, 8).unwrap();
    let x1 = a.inverse().unwrap().to_dense().unwrap();
    let x2 = a.inverse().unwrap().to_dense().unwrap();
    assert_eq!(x1.max_abs_diff(&x2), 0.0, "same input ⇒ same output bits");
}

#[test]
fn rng_stream_stability_guard() {
    // The experiment seeds in EXPERIMENTS.md depend on this stream; if this
    // test moves, every recorded number must be regenerated.
    let mut r = Rng::new(42);
    assert_eq!(r.next_u64(), {
        let mut r2 = Rng::new(42);
        r2.next_u64()
    });
    let vals: Vec<u64> = (0..4).map(|_| r.next_u64() % 1000).collect();
    assert_eq!(vals.len(), 4);
}

// ---------------- work-stealing executor (this PR's headline) ----------

/// Acceptance (property): running every stage on the work-stealing
/// partition runtime — at 2, 4 and 8 lanes, with and without fault
/// injection — is **bit-identical** to the sequential executor for both
/// algorithms. The runtime's determinism contract (canonical-order shuffle
/// merges, executor-independent fault streams) is exactly this.
#[test]
fn parallel_execution_is_bit_identical_to_sequential_property() {
    forall(
        "exec_threads ∈ {2,4,8} ≡ sequential, bit for bit",
        0xE8EC,
        2,
        |r| (r.next_u64(), 1 + r.next_u64() % 0xFFFF),
        |&(matrix_seed, fault_seed)| {
            let run = |algo: &str, exec_threads: usize, chaos: bool| {
                let mut cfg = ClusterConfig::local(4);
                cfg.exec_threads = exec_threads;
                if chaos {
                    cfg.fault_seed = Some(fault_seed);
                    cfg.fault_rate = 0.1;
                    cfg.task_retries = 5;
                }
                let session = SpinSession::builder()
                    .cluster_config(cfg)
                    .build()
                    .map_err(|e| e.to_string())?;
                let a = session
                    .random_seeded(128, 16, matrix_seed)
                    .map_err(|e| e.to_string())?;
                let inv = a.inverse_with(algo).map_err(|e| e.to_string())?;
                inv.to_dense().map_err(|e| e.to_string())
            };
            for algo in ["spin", "lu"] {
                for chaos in [false, true] {
                    let sequential = run(algo, 1, chaos)?;
                    for threads in [2usize, 4, 8] {
                        let parallel = run(algo, threads, chaos)?;
                        for (i, (p, s)) in
                            parallel.data().iter().zip(sequential.data()).enumerate()
                        {
                            if p.to_bits() != s.to_bits() {
                                return Err(format!(
                                    "{algo} chaos={chaos} exec_threads={threads} \
                                     seed={matrix_seed:#x}: element {i} differs: \
                                     {p:e} vs {s:e}"
                                ));
                            }
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// CI speedup smoke (`SPIN_EXEC_SPEEDUP=1`, release build): at n = 512 /
/// block 64 the 4-lane executor must beat the sequential one by ≥ 1.3×
/// wall clock. Skipped with a notice on hosts with < 4 cores or when the
/// env gate is unset (debug-build timings are noise).
#[test]
fn exec_parallel_speedup_smoke() {
    if std::env::var("SPIN_EXEC_SPEEDUP").is_err() {
        println!("skipping speedup smoke: SPIN_EXEC_SPEEDUP not set");
        return;
    }
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    if cores < 4 {
        println!("skipping speedup smoke: only {cores} cores available (need 4)");
        return;
    }
    let wall = |exec_threads: usize| {
        let mut cfg = ClusterConfig::local(4);
        cfg.exec_threads = exec_threads;
        let mut job = JobConfig::new(512, 64);
        job.seed = 0x5EED;
        let r = spin::experiments::run_inversion(&cfg, &job, "spin").unwrap();
        assert!(r.residual < 1e-8, "residual {:.3e}", r.residual);
        r.real_secs
    };
    // Warm up once so allocator/page-cache effects don't skew lane 1.
    let _ = wall(1);
    let sequential = wall(1);
    let parallel = wall(4);
    let speedup = sequential / parallel;
    println!("speedup smoke: sequential {sequential:.3}s, 4 lanes {parallel:.3}s ({speedup:.2}x)");
    assert!(
        speedup >= 1.3,
        "4-lane executor must be ≥ 1.3x faster: {sequential:.3}s vs {parallel:.3}s ({speedup:.2}x)"
    );
}
