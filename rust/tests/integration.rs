//! Cross-module integration tests: the full distributed stack (cluster +
//! blockmatrix + algos + runtime), both backends, storage round-trips,
//! and the experiment harness glue.
//!
//! XLA-backend tests are gated on `artifacts/manifest.json` (built by
//! `make artifacts`); they are skipped, not failed, without it.

use std::path::{Path, PathBuf};

use spin::algos::{lu_inverse_distributed, spin_inverse, strassen_inverse_serial, Algorithm};
use spin::blockmatrix::BlockMatrix;
use spin::cluster::Cluster;
use spin::config::{BackendKind, ClusterConfig, GeneratorKind, JobConfig, LeafMethod};
use spin::linalg::{inverse_residual, Matrix};
use spin::runtime::{make_backend, NativeBackend, XlaBackend};
use spin::util::check::forall;
use spin::util::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn paper_cluster() -> Cluster {
    Cluster::new(ClusterConfig::paper())
}

#[test]
fn spin_full_grid_sweep_native() {
    let cluster = paper_cluster();
    for (n, bs) in [(16usize, 4usize), (32, 4), (32, 8), (64, 8), (64, 16), (128, 32)] {
        let mut job = JobConfig::new(n, bs);
        job.seed = 0x100 + n as u64 + bs as u64;
        let a = BlockMatrix::random(&job).unwrap();
        let inv = spin_inverse(&cluster, &NativeBackend, &a, &job).unwrap();
        let resid = inverse_residual(&a.to_dense().unwrap(), &inv.to_dense().unwrap());
        assert!(resid < 1e-9, "spin n={n} bs={bs}: {resid:.3e}");
    }
}

#[test]
fn lu_full_grid_sweep_native() {
    let cluster = paper_cluster();
    for (n, bs) in [(16usize, 4usize), (32, 8), (64, 16), (128, 32)] {
        let mut job = JobConfig::new(n, bs);
        job.seed = 0x200 + n as u64;
        let a = BlockMatrix::random(&job).unwrap();
        let inv = lu_inverse_distributed(&cluster, &NativeBackend, &a, &job).unwrap();
        let resid = inverse_residual(&a.to_dense().unwrap(), &inv.to_dense().unwrap());
        assert!(resid < 1e-9, "lu n={n} bs={bs}: {resid:.3e}");
    }
}

#[test]
fn spin_matches_serial_strassen_property() {
    forall(
        "distributed SPIN ≡ serial Algorithm 1",
        0x31,
        6,
        |r| {
            let n = 1usize << (4 + r.next_usize(2)); // 16 or 32
            let bs = 1usize << (2 + r.next_usize(2)); // 4 or 8
            (n, bs.min(n), r.next_u64())
        },
        |&(n, bs, seed)| {
            let cluster = paper_cluster();
            let mut job = JobConfig::new(n, bs);
            job.seed = seed;
            let a = BlockMatrix::random(&job).unwrap();
            let dense = a.to_dense().unwrap();
            let dist = spin_inverse(&cluster, &NativeBackend, &a, &job)
                .map_err(|e| e.to_string())?
                .to_dense()
                .unwrap();
            let serial = strassen_inverse_serial(&dense, bs).map_err(|e| e.to_string())?;
            let diff = dist.max_abs_diff(&serial);
            if diff < 1e-7 {
                Ok(())
            } else {
                Err(format!("distributed vs serial diff {diff}"))
            }
        },
    );
}

#[test]
fn spd_and_both_leaf_methods() {
    let cluster = paper_cluster();
    for leaf in [LeafMethod::Lu, LeafMethod::GaussJordan] {
        let mut job = JobConfig::new(64, 16);
        job.generator = GeneratorKind::Spd;
        job.leaf = leaf;
        let a = BlockMatrix::random(&job).unwrap();
        let inv = spin_inverse(&cluster, &NativeBackend, &a, &job).unwrap();
        let resid = inverse_residual(&a.to_dense().unwrap(), &inv.to_dense().unwrap());
        assert!(resid < 1e-9, "{leaf:?}: {resid:.3e}");
    }
}

#[test]
fn virtual_time_accumulates_and_resets_across_runs() {
    let cluster = paper_cluster();
    let job = JobConfig::new(32, 8);
    let a = BlockMatrix::random(&job).unwrap();
    let _ = spin_inverse(&cluster, &NativeBackend, &a, &job).unwrap();
    let t1 = cluster.virtual_secs();
    assert!(t1 > 0.0);
    let _ = spin_inverse(&cluster, &NativeBackend, &a, &job).unwrap();
    assert!(cluster.virtual_secs() > t1, "clock must accumulate");
    cluster.reset();
    assert_eq!(cluster.virtual_secs(), 0.0);
}

#[test]
fn block_store_round_trip_via_cli_layer() {
    let dir = std::env::temp_dir().join(format!("spin_it_store_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let code = spin::cli::run(
        format!("gen --n 32 --block-size 8 --seed 5 --out {}", dir.display())
            .split_whitespace()
            .map(String::from)
            .collect(),
    );
    assert_eq!(code, 0);
    let meta = spin::ser::bin::read_block_store_meta(&dir).unwrap();
    assert_eq!(meta.nblocks, 4);
    assert_eq!(meta.block_size, 8);
    // Reassemble and compare against the same-seed generator output.
    let mut dense = Matrix::zeros(32, 32);
    for i in 0..4 {
        for j in 0..4 {
            let blk = spin::ser::bin::read_block(&dir, i, j).unwrap();
            dense.set_submatrix(i * 8, j * 8, &blk).unwrap();
        }
    }
    let mut job = JobConfig::new(32, 8);
    job.seed = 5;
    let want = BlockMatrix::random(&job).unwrap().to_dense().unwrap();
    assert_eq!(dense.max_abs_diff(&want), 0.0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn make_backend_dispatches() {
    let mut cfg = ClusterConfig::paper();
    cfg.backend = BackendKind::Native;
    assert_eq!(make_backend(&cfg).unwrap().name(), "native");
    cfg.backend = BackendKind::Xla;
    cfg.artifacts_dir = PathBuf::from("/definitely/missing");
    assert!(make_backend(&cfg).is_err());
}

// ---------------- XLA-backend integration (gated on artifacts) ----------

#[test]
fn spin_distributed_on_xla_backend() {
    let Some(dir) = artifacts_dir() else { return };
    let be = XlaBackend::new(dir).unwrap();
    let cluster = paper_cluster();
    let mut job = JobConfig::new(128, 32);
    job.leaf = LeafMethod::GaussJordan;
    let a = BlockMatrix::random(&job).unwrap();
    let inv = spin_inverse(&cluster, &be, &a, &job).unwrap();
    let resid = inverse_residual(&a.to_dense().unwrap(), &inv.to_dense().unwrap());
    assert!(resid < 1e-9, "xla spin residual {resid:.3e}");
    assert!(be.executed_count() > 0, "PJRT path must actually execute");
    assert_eq!(be.fallback_count(), 0, "no native fallbacks expected");
}

#[test]
fn lu_distributed_on_xla_backend_is_fully_pjrt() {
    let Some(dir) = artifacts_dir() else { return };
    let be = XlaBackend::new(dir).unwrap();
    let cluster = paper_cluster();
    let job = JobConfig::new(64, 16);
    let a = BlockMatrix::random(&job).unwrap();
    let inv = lu_inverse_distributed(&cluster, &be, &a, &job).unwrap();
    let resid = inverse_residual(&a.to_dense().unwrap(), &inv.to_dense().unwrap());
    assert!(resid < 1e-9, "xla lu residual {resid:.3e}");
    // Baseline leaves (lu_factor / invert_lower / invert_upper) must also
    // run through PJRT — fairness of the SPIN-vs-LU comparison.
    assert_eq!(be.fallback_count(), 0, "LU leaves must not fall back");
}

#[test]
fn fused_leaf_2x2_on_xla_matches_unfused() {
    let Some(dir) = artifacts_dir() else { return };
    let be = XlaBackend::new(dir).unwrap();
    let c1 = paper_cluster();
    let c2 = paper_cluster();
    let mut job = JobConfig::new(64, 32);
    job.leaf = LeafMethod::GaussJordan;
    let a = BlockMatrix::random(&job).unwrap();
    let plain = spin_inverse(&c1, &be, &a, &job).unwrap();
    job.fuse_leaf_2x2 = true;
    let fused = spin_inverse(&c2, &be, &a, &job).unwrap();
    let diff = plain
        .to_dense()
        .unwrap()
        .max_abs_diff(&fused.to_dense().unwrap());
    assert!(diff < 1e-8, "fused vs plain diff {diff}");
    // The fused path collapses that level's stages into one task.
    let plain_stages = c1.metrics().stages().len();
    let fused_stages = c2.metrics().stages().len();
    assert!(
        fused_stages < plain_stages,
        "fusion should reduce stage count: {fused_stages} vs {plain_stages}"
    );
}

#[test]
fn xla_and_native_agree_numerically() {
    let Some(dir) = artifacts_dir() else { return };
    let be = XlaBackend::new(dir).unwrap();
    let c1 = paper_cluster();
    let c2 = paper_cluster();
    let mut job = JobConfig::new(64, 16);
    job.leaf = LeafMethod::GaussJordan;
    let a = BlockMatrix::random(&job).unwrap();
    let x = spin_inverse(&c1, &be, &a, &job).unwrap().to_dense().unwrap();
    let n = spin_inverse(&c2, &NativeBackend, &a, &job)
        .unwrap()
        .to_dense()
        .unwrap();
    let diff = x.max_abs_diff(&n);
    assert!(diff < 1e-8, "xla vs native diff {diff}");
}

#[test]
fn experiment_harness_runs_on_xla() {
    let Some(_dir) = artifacts_dir() else { return };
    let mut cfg = ClusterConfig::paper();
    cfg.backend = BackendKind::Xla;
    let mut job = JobConfig::new(64, 16);
    job.leaf = LeafMethod::GaussJordan;
    let r = spin::experiments::run_inversion(&cfg, &job, Algorithm::Spin).unwrap();
    assert!(r.residual < 1e-9);
    assert!(r.virtual_secs > 0.0);
}

#[test]
fn multithreaded_workers_with_xla_thread_local_engines() {
    let Some(dir) = artifacts_dir() else { return };
    let be = XlaBackend::new(dir).unwrap();
    let mut cfg = ClusterConfig::paper();
    cfg.worker_threads = 3; // forces engines on several threads
    let cluster = Cluster::new(cfg);
    let mut job = JobConfig::new(64, 16);
    job.leaf = LeafMethod::GaussJordan;
    let a = BlockMatrix::random(&job).unwrap();
    let inv = spin_inverse(&cluster, &be, &a, &job).unwrap();
    let resid = inverse_residual(&a.to_dense().unwrap(), &inv.to_dense().unwrap());
    assert!(resid < 1e-9, "mt xla residual {resid:.3e}");
}

#[test]
fn figure5_replay_is_monotone() {
    let cluster = ClusterConfig::paper();
    let mut scale = spin::experiments::Scale::smoke();
    scale.sizes = vec![128];
    let rows = spin::experiments::figure5::run(&cluster, &scale, 9).unwrap();
    spin::experiments::figure5::check_shape(&rows).unwrap();
}

#[test]
fn seeded_rerun_is_bitwise_identical() {
    let cluster = paper_cluster();
    let job = JobConfig::new(32, 8);
    let a = BlockMatrix::random(&job).unwrap();
    let x1 = spin_inverse(&cluster, &NativeBackend, &a, &job)
        .unwrap()
        .to_dense()
        .unwrap();
    let x2 = spin_inverse(&cluster, &NativeBackend, &a, &job)
        .unwrap()
        .to_dense()
        .unwrap();
    assert_eq!(x1.max_abs_diff(&x2), 0.0, "same input ⇒ same output bits");
}

#[test]
fn rng_stream_stability_guard() {
    // The experiment seeds in EXPERIMENTS.md depend on this stream; if this
    // test moves, every recorded number must be regenerated.
    let mut r = Rng::new(42);
    assert_eq!(r.next_u64(), {
        let mut r2 = Rng::new(42);
        r2.next_u64()
    });
    let vals: Vec<u64> = (0..4).map(|_| r.next_u64() % 1000).collect();
    assert_eq!(vals.len(), 4);
}
