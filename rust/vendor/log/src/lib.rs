//! Minimal in-tree stand-in for the `log` crate's facade — just the subset
//! this workspace uses: the five level macros, `Level`/`LevelFilter`, the
//! `Log` trait, and the global logger registration functions. API-compatible
//! with the real crate for these items, so swapping the path dependency for
//! crates.io `log` requires no source changes.

#![forbid(unsafe_code)]

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity level of one log record (most severe first).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Maximum-verbosity filter installed via [`set_max_level`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        f.write_str(s)
    }
}

/// Metadata about a record: its level and target (module path).
#[derive(Clone, Debug)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record: metadata plus the formatted message.
#[derive(Clone, Debug)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A sink for log records.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install the global logger; errors if one is already installed.
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global maximum verbosity.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// Current global maximum verbosity.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

/// Macro plumbing — not public API.
#[doc(hidden)]
pub fn __private_api_log(level: Level, target: &str, args: fmt::Arguments) {
    if level as usize > MAX_LEVEL.load(Ordering::Relaxed) {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let metadata = Metadata { level, target };
        if logger.enabled(&metadata) {
            logger.log(&Record { metadata, args });
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__private_api_log($lvl, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;

    struct CountingLogger;

    impl Log for CountingLogger {
        fn enabled(&self, metadata: &Metadata) -> bool {
            metadata.level() <= max_level()
        }

        fn log(&self, record: &Record) {
            let _ = format!("{}: {}", record.target(), record.args());
        }

        fn flush(&self) {}
    }

    static TEST_LOGGER: CountingLogger = CountingLogger;

    #[test]
    fn facade_round_trip() {
        let _ = set_logger(&TEST_LOGGER);
        set_max_level(LevelFilter::Info);
        assert_eq!(max_level(), LevelFilter::Info);
        info!("hello {}", 42);
        debug!("filtered out {}", 1);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(Level::Error <= LevelFilter::Info);
    }

    #[test]
    fn second_set_logger_errors() {
        let _ = set_logger(&TEST_LOGGER);
        assert!(set_logger(&TEST_LOGGER).is_err());
    }
}
