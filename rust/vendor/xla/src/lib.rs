//! Compile-time stub of the `xla` (PJRT) bindings used by `spin::runtime`.
//!
//! The real bindings link against the XLA native libraries, which are not
//! part of the offline vendor set. This stub keeps the whole crate — and
//! everything written against the PJRT engine — compiling anywhere, while
//! failing fast at *runtime* with an actionable error the moment a PJRT
//! client is requested. [`Literal`] is implemented for real (it is a plain
//! host-side container), so layout round-trip code and its tests still run.
//!
//! Swapping this path dependency for the real crates.io bindings requires
//! no source changes in `spin`.

#![forbid(unsafe_code)]

use std::fmt;

/// Error type mirroring `xla::Error`.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: the XLA/PJRT native runtime is not part of this build \
         (vendored stub `xla` crate); rebuild against the real xla bindings \
         or use the `native` backend"
    ))
}

/// A host-side literal: shape + f64 payload (the only dtype this
/// workspace lowers).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: Vec<f64>,
}

impl Literal {
    /// Rank-0 literal holding one scalar.
    pub fn scalar(v: f64) -> Literal {
        Literal {
            dims: Vec::new(),
            data: vec![v],
        }
    }

    /// Rank-1 literal over a host slice.
    pub fn vec1(v: &[f64]) -> Literal {
        Literal {
            dims: vec![v.len() as i64],
            data: v.to_vec(),
        }
    }

    /// Reshape without changing the element count.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements cannot view as {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal {
            dims: dims.to_vec(),
            data: self.data.clone(),
        })
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Copy the payload out (f64 only in this workspace).
    pub fn to_vec<T: From<f64>>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&x| T::from(x)).collect())
    }

    /// Destructure a tuple literal. The stub never produces tuples (they
    /// only come back from PJRT execution), so this always errors.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

/// Stub PJRT client: construction always fails.
pub struct PjRtClient {
    _unconstructible: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Stub compiled executable (unreachable: no client can be built).
pub struct PjRtLoadedExecutable {
    _unconstructible: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: AsRef<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Stub device buffer (unreachable: no executable can be built).
pub struct PjRtBuffer {
    _unconstructible: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Stub HLO module proto (parse always errors: nothing can execute it).
pub struct HloModuleProto {
    _unconstructible: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(unavailable(&format!("HloModuleProto::from_text_file({path})")))
    }
}

/// Stub computation wrapper.
pub struct XlaComputation {
    _unconstructible: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            _unconstructible: (),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip() {
        let lit = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let lit = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(lit.dims(), &[2, 2]);
        assert_eq!(lit.to_vec::<f64>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn client_fails_fast_with_actionable_message() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("native backend"));
    }
}
